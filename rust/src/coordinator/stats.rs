//! Serving statistics: a fixed-size log₂ latency histogram (so
//! `ServeStats` stays `Copy` and crossing the worker/caller thread
//! boundary is a plain move) plus the per-coordinator counters with
//! p50/p95/p99 and throughput accessors.

use std::fmt;
use std::time::Duration;

/// Number of log₂ microsecond buckets. Bucket `b` holds latencies in
/// `[2^(b-1), 2^b)` µs (bucket 0 is `< 1 µs`), so 40 buckets cover
/// sub-microsecond through ~6 days — every latency a serving loop can
/// produce.
pub const LAT_BUCKETS: usize = 40;

/// Log-bucketed latency histogram. Quantiles are resolved to a bucket
/// upper bound, i.e. within 2× of the true value — the standard
/// serving-histogram tradeoff (HdrHistogram-shaped, power-of-two
/// buckets so recording is a `leading_zeros`).
#[derive(Debug, Clone, Copy)]
pub struct LatencyHist {
    counts: [u64; LAT_BUCKETS],
    total: u64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        LatencyHist { counts: [0; LAT_BUCKETS], total: 0 }
    }
}

impl LatencyHist {
    pub fn record(&mut self, lat: Duration) {
        let us = lat.as_micros() as u64;
        let bucket = (64 - us.leading_zeros() as usize).min(LAT_BUCKETS - 1);
        self.counts[bucket] += 1;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Merge another histogram into this one (shard/client fan-in).
    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += *b;
        }
        self.total += other.total;
    }

    /// Raw bucket counts — the wire form a shard server ships in a
    /// `StatsResp` frame.
    pub fn bucket_counts(&self) -> [u64; LAT_BUCKETS] {
        self.counts
    }

    /// Rebuild a histogram from wire-shipped bucket counts (the
    /// inverse of [`LatencyHist::bucket_counts`]); a short or long
    /// count vector is zero-padded / truncated into the local bucket
    /// layout so a version-skewed peer degrades instead of erroring.
    pub fn from_bucket_counts(counts: &[u64]) -> LatencyHist {
        let mut h = LatencyHist::default();
        for (a, b) in h.counts.iter_mut().zip(counts.iter()) {
            *a = *b;
        }
        h.total = h.counts.iter().sum();
        h
    }

    /// Latency at quantile `q` in [0, 1]: the upper bound of the bucket
    /// containing the q-th sample. Zero when empty.
    pub fn quantile(&self, q: f64) -> Duration {
        if self.total == 0 {
            return Duration::ZERO;
        }
        let target = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut cum = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return Duration::from_micros(1u64 << b);
            }
        }
        Duration::from_micros(1u64 << (LAT_BUCKETS - 1))
    }
}

/// Serving statistics (snapshot via [`super::Coordinator::shutdown`]).
#[derive(Debug, Default, Clone, Copy)]
pub struct ServeStats {
    pub requests: u64,
    pub batches: u64,
    /// Failed *batches* (each may span many requests); the per-request
    /// failure count is the client-side `LoadReport::errors`.
    pub errors: u64,
    /// Per-request latency, submit → response send.
    pub hist: LatencyHist,
    /// Worker lifetime (spawn → shutdown), the throughput denominator.
    pub elapsed: Duration,
    /// Table segments served as zeros because every host for the table
    /// was dead (net mode only). Each increment is one table across a
    /// whole batch — responses still succeed, quality degrades.
    pub degraded: u64,
    /// Embedding-store counters over the worker's table set, folded in
    /// at shutdown (zero accesses for dense tables): hot-tier hit rate,
    /// dequantized rows, resident bytes. See [`crate::store::StoreStats`].
    pub store: crate::store::StoreStats,
    /// Requests shed at admission by the QoS controller (deadline
    /// unmeetable / pressure), folded in at shutdown. These never
    /// count toward `requests`.
    pub shed_admission: u64,
    /// Hard rejections: the bounded admission queue was full.
    pub rejected_full: u64,
    /// Requests shed at batch formation (deadline already expired when
    /// the batch flushed). Counted in `requests` but answered with a
    /// typed `Overloaded` error instead of being served.
    pub shed_batch: u64,
    /// Responses delivered after their deadline had passed (served,
    /// but too late to be useful).
    pub deadline_missed: u64,
}

impl ServeStats {
    /// Fold stats from another process (shard server / second frontend)
    /// into this one. Counters and histograms add; `elapsed` takes the
    /// max because concurrent processes overlap in wall time — summing
    /// would undercount throughput by the fan-out factor.
    pub fn merge(&mut self, other: &ServeStats) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.errors += other.errors;
        self.degraded += other.degraded;
        self.store.accumulate(other.store);
        self.hist.merge(&other.hist);
        self.elapsed = self.elapsed.max(other.elapsed);
        self.shed_admission += other.shed_admission;
        self.rejected_full += other.rejected_full;
        self.shed_batch += other.shed_batch;
        self.deadline_missed += other.deadline_missed;
    }

    /// Total requests refused or abandoned by the QoS subsystem.
    pub fn shed(&self) -> u64 {
        self.shed_admission + self.rejected_full + self.shed_batch
    }

    pub fn p50(&self) -> Duration {
        self.hist.quantile(0.50)
    }
    pub fn p95(&self) -> Duration {
        self.hist.quantile(0.95)
    }
    pub fn p99(&self) -> Duration {
        self.hist.quantile(0.99)
    }
    /// Percentage of table segments served degraded (zeros because no
    /// host owned the table was alive). Each `degraded` increment is
    /// one table across one batch, so the denominator is
    /// `batches × tables`. Zero when nothing was served.
    pub fn degraded_pct(&self, tables: usize) -> f64 {
        let total = self.batches.saturating_mul(tables as u64);
        if total == 0 {
            0.0
        } else {
            100.0 * self.degraded as f64 / total as f64
        }
    }

    /// Requests per second over the worker's lifetime.
    pub fn throughput_rps(&self) -> f64 {
        if self.elapsed.is_zero() {
            0.0
        } else {
            self.requests as f64 / self.elapsed.as_secs_f64()
        }
    }
}

impl fmt::Display for ServeStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} req, {} batches, {} failed batches, {:.0} req/s, p50 {:.2?} p95 {:.2?} p99 {:.2?}",
            self.requests,
            self.batches,
            self.errors,
            self.throughput_rps(),
            self.p50(),
            self.p95(),
            self.p99()
        )?;
        if self.degraded > 0 {
            write!(f, ", {} degraded segments", self.degraded)?;
        }
        if self.shed() > 0 {
            write!(
                f,
                ", {} shed ({} admission / {} queue-full / {} batch)",
                self.shed(),
                self.shed_admission,
                self.rejected_full,
                self.shed_batch
            )?;
        }
        if self.deadline_missed > 0 {
            write!(f, ", {} deadline-missed", self.deadline_missed)?;
        }
        if self.store.accesses() > 0 {
            write!(
                f,
                ", store {:.1}% hot ({} dequants, {:.2} MiB resident)",
                self.store.hit_pct(),
                self.store.dequants,
                self.store.resident_bytes as f64 / (1024.0 * 1024.0)
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_resolve_to_bucket_upper_bounds() {
        let mut h = LatencyHist::default();
        for _ in 0..90 {
            h.record(Duration::from_micros(100)); // bucket [64, 128)
        }
        for _ in 0..10 {
            h.record(Duration::from_millis(3)); // bucket [2048, 4096) us
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.quantile(0.50), Duration::from_micros(128));
        assert_eq!(h.quantile(0.89), Duration::from_micros(128));
        assert_eq!(h.quantile(0.99), Duration::from_micros(4096));
        assert_eq!(h.quantile(1.0), Duration::from_micros(4096));
    }

    #[test]
    fn empty_hist_is_zero_and_merge_accumulates() {
        let mut a = LatencyHist::default();
        assert_eq!(a.quantile(0.99), Duration::ZERO);
        let mut b = LatencyHist::default();
        b.record(Duration::from_micros(10));
        a.merge(&b);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.quantile(0.5), Duration::from_micros(16));
    }

    #[test]
    fn extreme_latencies_clamp_to_last_bucket() {
        let mut h = LatencyHist::default();
        h.record(Duration::ZERO);
        h.record(Duration::from_secs(1 << 30));
        assert_eq!(h.count(), 2);
        assert_eq!(h.quantile(0.01), Duration::from_micros(1));
        assert_eq!(h.quantile(1.0), Duration::from_micros(1u64 << (LAT_BUCKETS - 1)));
    }

    #[test]
    fn empty_hist_quantiles_are_zero_at_every_q() {
        let h = LatencyHist::default();
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(h.quantile(q), Duration::ZERO, "q={q}");
        }
        // Merging an empty histogram is a no-op, not a corruption.
        let mut a = LatencyHist::default();
        a.record(Duration::from_micros(100));
        let before = a.quantile(0.5);
        a.merge(&LatencyHist::default());
        assert_eq!(a.count(), 1);
        assert_eq!(a.quantile(0.5), before);
    }

    #[test]
    fn bucket_counts_round_trip_through_the_wire_form() {
        let mut h = LatencyHist::default();
        for us in [1u64, 7, 100, 5000, 1 << 20] {
            h.record(Duration::from_micros(us));
        }
        let wire = h.bucket_counts().to_vec();
        let back = LatencyHist::from_bucket_counts(&wire);
        assert_eq!(back.count(), h.count());
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(back.quantile(q), h.quantile(q), "q={q}");
        }
        // Version skew: short and long wire vectors still decode.
        assert_eq!(LatencyHist::from_bucket_counts(&[3, 2]).count(), 5);
        let long: Vec<u64> = (0..LAT_BUCKETS as u64 + 8).map(|_| 1).collect();
        assert_eq!(LatencyHist::from_bucket_counts(&long).count(), LAT_BUCKETS as u64);
    }

    #[test]
    fn cross_process_merge_matches_single_process_recording() {
        // Record the same samples into one hist and into two "process"
        // hists that are then merged — quantiles must agree exactly.
        let samples: Vec<u64> = (0..200).map(|i| 10 + i * 37).collect();
        let mut single = LatencyHist::default();
        let mut p1 = LatencyHist::default();
        let mut p2 = LatencyHist::default();
        for (i, &us) in samples.iter().enumerate() {
            let d = Duration::from_micros(us);
            single.record(d);
            if i % 2 == 0 {
                p1.record(d);
            } else {
                p2.record(d);
            }
        }
        let mut merged = LatencyHist::default();
        merged.merge(&p1);
        merged.merge(&p2);
        assert_eq!(merged.count(), single.count());
        for q in [0.01, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(merged.quantile(q), single.quantile(q), "q={q}");
        }
    }

    #[test]
    fn serve_stats_merge_sums_counters_and_takes_max_elapsed() {
        use crate::store::StoreStats;
        let mut a = ServeStats {
            requests: 100,
            batches: 10,
            errors: 1,
            degraded: 2,
            store: StoreStats { hits: 90, misses: 10, dequants: 10, resident_bytes: 1000 },
            elapsed: Duration::from_secs(4),
            ..Default::default()
        };
        for _ in 0..100 {
            a.hist.record(Duration::from_micros(50));
        }
        let mut b = ServeStats {
            requests: 300,
            batches: 30,
            errors: 0,
            degraded: 5,
            store: StoreStats { hits: 10, misses: 90, dequants: 90, resident_bytes: 500 },
            elapsed: Duration::from_secs(2),
            ..Default::default()
        };
        for _ in 0..300 {
            b.hist.record(Duration::from_micros(200));
        }
        a.merge(&b);
        assert_eq!(a.requests, 400);
        assert_eq!(a.batches, 40);
        assert_eq!(a.errors, 1);
        assert_eq!(a.degraded, 7);
        // store counters add across processes, like every other counter
        assert_eq!(
            a.store,
            StoreStats { hits: 100, misses: 100, dequants: 100, resident_bytes: 1500 }
        );
        assert_eq!(a.store.hit_pct(), 50.0);
        assert_eq!(a.hist.count(), 400);
        // Overlapping processes: elapsed is the max, so throughput is
        // 400 req / 4 s, not 400 / 6 s.
        assert_eq!(a.elapsed, Duration::from_secs(4));
        assert!((a.throughput_rps() - 100.0).abs() < 1e-9);
        // p50 lands in the 300-sample bucket ([128, 256) µs).
        assert_eq!(a.p50(), Duration::from_micros(256));
    }

    #[test]
    fn degraded_pct_is_segments_over_batches_times_tables() {
        let s = ServeStats { batches: 10, degraded: 8, ..Default::default() };
        // 8 degraded segments out of 10 batches × 4 tables = 20%
        assert!((s.degraded_pct(4) - 20.0).abs() < 1e-9);
        assert_eq!(s.degraded_pct(0), 0.0, "zero tables never divides by zero");
        let empty = ServeStats::default();
        assert_eq!(empty.degraded_pct(4), 0.0);
    }

    #[test]
    fn degraded_counter_shows_in_display_only_when_nonzero() {
        let mut s = ServeStats { requests: 1, ..Default::default() };
        assert!(!format!("{s}").contains("degraded"));
        s.degraded = 3;
        assert!(format!("{s}").contains("3 degraded segments"));
    }

    #[test]
    fn shed_counters_merge_and_display_only_when_nonzero() {
        let mut a = ServeStats { requests: 10, ..Default::default() };
        assert!(!format!("{a}").contains("shed"));
        assert!(!format!("{a}").contains("deadline-missed"));
        let b = ServeStats {
            shed_admission: 3,
            rejected_full: 2,
            shed_batch: 1,
            deadline_missed: 4,
            ..Default::default()
        };
        a.merge(&b);
        a.merge(&b);
        assert_eq!(a.shed(), 12);
        assert_eq!(a.deadline_missed, 8);
        let text = format!("{a}");
        assert!(text.contains("12 shed (6 admission / 4 queue-full / 2 batch)"), "{text}");
        assert!(text.contains("8 deadline-missed"), "{text}");
    }

    #[test]
    fn serve_stats_throughput_and_display() {
        let mut s = ServeStats::default();
        assert_eq!(s.throughput_rps(), 0.0);
        s.requests = 100;
        s.elapsed = Duration::from_secs(2);
        for _ in 0..100 {
            s.hist.record(Duration::from_micros(50));
        }
        assert!((s.throughput_rps() - 50.0).abs() < 1e-9);
        let text = format!("{s}");
        assert!(text.contains("100 req"), "{text}");
        assert!(text.contains("failed batches"), "{text}");
        assert!(text.contains("p99"), "{text}");
    }
}
