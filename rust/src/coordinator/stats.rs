//! Serving statistics: a fixed-size log₂ latency histogram (so
//! `ServeStats` stays `Copy` and crossing the worker/caller thread
//! boundary is a plain move) plus the per-coordinator counters with
//! p50/p95/p99 and throughput accessors.

use std::fmt;
use std::time::Duration;

/// Number of log₂ microsecond buckets. Bucket `b` holds latencies in
/// `[2^(b-1), 2^b)` µs (bucket 0 is `< 1 µs`), so 40 buckets cover
/// sub-microsecond through ~6 days — every latency a serving loop can
/// produce.
pub const LAT_BUCKETS: usize = 40;

/// Log-bucketed latency histogram. Quantiles are resolved to a bucket
/// upper bound, i.e. within 2× of the true value — the standard
/// serving-histogram tradeoff (HdrHistogram-shaped, power-of-two
/// buckets so recording is a `leading_zeros`).
#[derive(Debug, Clone, Copy)]
pub struct LatencyHist {
    counts: [u64; LAT_BUCKETS],
    total: u64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        LatencyHist { counts: [0; LAT_BUCKETS], total: 0 }
    }
}

impl LatencyHist {
    pub fn record(&mut self, lat: Duration) {
        let us = lat.as_micros() as u64;
        let bucket = (64 - us.leading_zeros() as usize).min(LAT_BUCKETS - 1);
        self.counts[bucket] += 1;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Merge another histogram into this one (shard/client fan-in).
    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += *b;
        }
        self.total += other.total;
    }

    /// Latency at quantile `q` in [0, 1]: the upper bound of the bucket
    /// containing the q-th sample. Zero when empty.
    pub fn quantile(&self, q: f64) -> Duration {
        if self.total == 0 {
            return Duration::ZERO;
        }
        let target = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut cum = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return Duration::from_micros(1u64 << b);
            }
        }
        Duration::from_micros(1u64 << (LAT_BUCKETS - 1))
    }
}

/// Serving statistics (snapshot via [`super::Coordinator::shutdown`]).
#[derive(Debug, Default, Clone, Copy)]
pub struct ServeStats {
    pub requests: u64,
    pub batches: u64,
    /// Failed *batches* (each may span many requests); the per-request
    /// failure count is the client-side `LoadReport::errors`.
    pub errors: u64,
    /// Per-request latency, submit → response send.
    pub hist: LatencyHist,
    /// Worker lifetime (spawn → shutdown), the throughput denominator.
    pub elapsed: Duration,
}

impl ServeStats {
    pub fn p50(&self) -> Duration {
        self.hist.quantile(0.50)
    }
    pub fn p95(&self) -> Duration {
        self.hist.quantile(0.95)
    }
    pub fn p99(&self) -> Duration {
        self.hist.quantile(0.99)
    }
    /// Requests per second over the worker's lifetime.
    pub fn throughput_rps(&self) -> f64 {
        if self.elapsed.is_zero() {
            0.0
        } else {
            self.requests as f64 / self.elapsed.as_secs_f64()
        }
    }
}

impl fmt::Display for ServeStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} req, {} batches, {} failed batches, {:.0} req/s, p50 {:.2?} p95 {:.2?} p99 {:.2?}",
            self.requests,
            self.batches,
            self.errors,
            self.throughput_rps(),
            self.p50(),
            self.p95(),
            self.p99()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_resolve_to_bucket_upper_bounds() {
        let mut h = LatencyHist::default();
        for _ in 0..90 {
            h.record(Duration::from_micros(100)); // bucket [64, 128)
        }
        for _ in 0..10 {
            h.record(Duration::from_millis(3)); // bucket [2048, 4096) us
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.quantile(0.50), Duration::from_micros(128));
        assert_eq!(h.quantile(0.89), Duration::from_micros(128));
        assert_eq!(h.quantile(0.99), Duration::from_micros(4096));
        assert_eq!(h.quantile(1.0), Duration::from_micros(4096));
    }

    #[test]
    fn empty_hist_is_zero_and_merge_accumulates() {
        let mut a = LatencyHist::default();
        assert_eq!(a.quantile(0.99), Duration::ZERO);
        let mut b = LatencyHist::default();
        b.record(Duration::from_micros(10));
        a.merge(&b);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.quantile(0.5), Duration::from_micros(16));
    }

    #[test]
    fn extreme_latencies_clamp_to_last_bucket() {
        let mut h = LatencyHist::default();
        h.record(Duration::ZERO);
        h.record(Duration::from_secs(1 << 30));
        assert_eq!(h.count(), 2);
        assert_eq!(h.quantile(0.01), Duration::from_micros(1));
        assert_eq!(h.quantile(1.0), Duration::from_micros(1u64 << (LAT_BUCKETS - 1)));
    }

    #[test]
    fn serve_stats_throughput_and_display() {
        let mut s = ServeStats::default();
        assert_eq!(s.throughput_rps(), 0.0);
        s.requests = 100;
        s.elapsed = Duration::from_secs(2);
        for _ in 0..100 {
            s.hist.record(Duration::from_micros(50));
        }
        assert!((s.throughput_rps() - 50.0).abs() < 1e-9);
        let text = format!("{s}");
        assert!(text.contains("100 req"), "{text}");
        assert!(text.contains("failed batches"), "{text}");
        assert!(text.contains("p99"), "{text}");
    }
}
