//! Dynamic batcher: groups requests into model-sized batches under a
//! latency bound (classic serving tradeoff). Pure state machine —
//! thread plumbing lives in `server.rs` so this is unit-testable.
//!
//! Flush triggers, in priority order:
//! 1. size — `max_batch` requests are pending;
//! 2. lookup budget — the accumulated lookup count would exceed
//!    `max_lookups`, so a few fat multi-table requests can't starve a
//!    batch of small ones (the forming batch closes *before* the fat
//!    request joins; a single request over budget forms its own batch);
//! 3. time — the oldest pending request has waited `max_wait`.

use super::Request;
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy)]
pub struct BatchOptions {
    /// Flush when this many requests are waiting (= compiled batch).
    pub max_batch: usize,
    /// Flush a non-empty batch this long after its first request.
    pub max_wait: Duration,
    /// Flush before the accumulated lookup count (across all tables of
    /// all pending requests) exceeds this budget. `usize::MAX`
    /// (default) disables the size-aware trigger.
    pub max_lookups: usize,
}

impl Default for BatchOptions {
    fn default() -> Self {
        BatchOptions {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
            max_lookups: usize::MAX,
        }
    }
}

/// A flushed batch. `formed_at` is the arrival time of its oldest
/// request — the authoritative start of the `batch_form` span and of
/// queue-delay accounting (taken from the batch itself, not sampled
/// from the batcher around the mutating call).
#[derive(Debug)]
pub struct Batch {
    pub reqs: Vec<Request>,
    pub formed_at: Instant,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.reqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.reqs.is_empty()
    }
}

/// Accumulates requests; `push`/`poll` report when a batch is ready.
pub struct Batcher {
    opts: BatchOptions,
    pending: Vec<Request>,
    /// Lookup count accumulated across `pending`.
    lookups: usize,
    oldest: Option<Instant>,
    pub batches_emitted: u64,
    pub requests_seen: u64,
}

fn lookup_cost(req: &Request) -> usize {
    req.lookups.iter().map(|t| t.len()).sum()
}

impl Batcher {
    pub fn new(opts: BatchOptions) -> Self {
        Batcher {
            opts,
            pending: Vec::new(),
            lookups: 0,
            oldest: None,
            batches_emitted: 0,
            requests_seen: 0,
        }
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Lookup count accumulated across the pending requests.
    pub fn pending_lookups(&self) -> usize {
        self.lookups
    }

    /// Add a request; returns a ready batch if one formed.
    ///
    /// When the new request would blow the lookup budget of a non-empty
    /// forming batch, the forming batch is returned and the new request
    /// starts the next one — so the returned batch may not contain the
    /// request just pushed. Callers tracking per-request state must
    /// consume exactly `batch.len()` entries, not "everything so far".
    pub fn push(&mut self, req: Request, now: Instant) -> Option<Batch> {
        let cost = lookup_cost(&req);
        let pre = if !self.pending.is_empty()
            && self.lookups.saturating_add(cost) > self.opts.max_lookups
        {
            self.flush()
        } else {
            None
        };
        if self.pending.is_empty() {
            self.oldest = Some(now);
        }
        self.pending.push(req);
        self.lookups += cost;
        self.requests_seen += 1;
        if pre.is_some() {
            // the over-budget closure above; the fresh batch (holding
            // only the new request) flushes on its own trigger later
            return pre;
        }
        if self.pending.len() >= self.opts.max_batch || self.lookups >= self.opts.max_lookups {
            return self.flush();
        }
        None
    }

    /// Time-based flush check.
    pub fn poll(&mut self, now: Instant) -> Option<Batch> {
        match self.oldest {
            Some(t0) if !self.pending.is_empty() && now.duration_since(t0) >= self.opts.max_wait => {
                self.flush()
            }
            _ => None,
        }
    }

    /// Deadline for the next time-based flush (for channel timeouts).
    pub fn deadline(&self) -> Option<Instant> {
        self.oldest.map(|t0| t0 + self.opts.max_wait)
    }

    /// Arrival time of the oldest pending request (`None` when empty).
    pub fn oldest(&self) -> Option<Instant> {
        self.oldest
    }

    /// Drain whatever is pending (shutdown path). `None` when empty.
    pub fn flush(&mut self) -> Option<Batch> {
        if self.pending.is_empty() {
            return None;
        }
        // `oldest` is always Some while pending is non-empty; the
        // fallback is unreachable but keeps this panic-free
        let formed_at = self.oldest.take().unwrap_or_else(Instant::now);
        self.lookups = 0;
        self.batches_emitted += 1;
        Some(Batch { reqs: std::mem::take(&mut self.pending), formed_at })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request { id, lookups: vec![vec![1]], dense: vec![0.0] }
    }

    /// A request with `n` lookups in one table.
    fn fat(id: u64, n: usize) -> Request {
        Request { id, lookups: vec![(0..n as i32).collect()], dense: vec![0.0] }
    }

    #[test]
    fn flushes_on_size() {
        let mut b = Batcher::new(BatchOptions {
            max_batch: 3,
            max_wait: Duration::from_secs(10),
            ..Default::default()
        });
        let t = Instant::now();
        assert!(b.push(req(0), t).is_none());
        assert!(b.push(req(1), t).is_none());
        let batch = b.push(req(2), t).expect("full");
        assert_eq!(batch.len(), 3);
        assert_eq!(b.pending(), 0);
        assert_eq!(b.batches_emitted, 1);
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b = Batcher::new(BatchOptions {
            max_batch: 100,
            max_wait: Duration::from_millis(5),
            ..Default::default()
        });
        let t0 = Instant::now();
        b.push(req(0), t0);
        assert!(b.poll(t0 + Duration::from_millis(1)).is_none());
        let batch = b.poll(t0 + Duration::from_millis(6)).expect("deadline");
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn flushes_on_lookup_budget_before_fat_request_joins() {
        let mut b = Batcher::new(BatchOptions {
            max_batch: 100,
            max_wait: Duration::from_secs(10),
            max_lookups: 8,
        });
        let t = Instant::now();
        assert!(b.push(fat(0, 3), t).is_none());
        assert!(b.push(fat(1, 3), t).is_none());
        // 6 + 4 > 8: the forming batch closes without the fat request
        let batch = b.push(fat(2, 4), t).expect("budget flush");
        assert_eq!(batch.reqs.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.pending(), 1, "the fat request starts the next batch");
        assert_eq!(b.pending_lookups(), 4);
    }

    #[test]
    fn single_request_over_budget_forms_its_own_batch() {
        let mut b = Batcher::new(BatchOptions {
            max_batch: 100,
            max_wait: Duration::from_secs(10),
            max_lookups: 8,
        });
        let t = Instant::now();
        let batch = b.push(fat(0, 20), t).expect("immediate singleton flush");
        assert_eq!(batch.len(), 1);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn every_request_in_exactly_one_batch() {
        let mut b = Batcher::new(BatchOptions {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        });
        let t0 = Instant::now();
        let mut seen = Vec::new();
        for i in 0..10 {
            if let Some(batch) = b.push(req(i), t0) {
                seen.extend(batch.reqs.iter().map(|r| r.id));
            }
        }
        if let Some(batch) = b.poll(t0 + Duration::from_millis(2)) {
            seen.extend(batch.reqs.iter().map(|r| r.id));
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn oldest_tracks_first_arrival_and_resets_on_flush() {
        let mut b = Batcher::new(BatchOptions {
            max_batch: 3,
            max_wait: Duration::from_secs(10),
            ..Default::default()
        });
        assert!(b.oldest().is_none());
        let t0 = Instant::now();
        b.push(req(0), t0);
        b.push(req(1), t0 + Duration::from_millis(1));
        assert_eq!(b.oldest(), Some(t0));
        b.flush();
        assert!(b.oldest().is_none());
    }

    /// Regression (formed-at bookkeeping): every flushed batch carries
    /// the arrival time of *its own* oldest request — including the
    /// batch formed right after a flush, which used to inherit a stale
    /// or `Instant::now()` timestamp from the caller sampling
    /// `oldest()` around the mutating call.
    #[test]
    fn formed_at_is_the_batch_own_oldest_arrival() {
        let mut b = Batcher::new(BatchOptions {
            max_batch: 2,
            max_wait: Duration::from_secs(10),
            ..Default::default()
        });
        let t0 = Instant::now();
        let t1 = t0 + Duration::from_millis(3);
        let t2 = t0 + Duration::from_millis(9);
        b.push(req(0), t0);
        let first = b.push(req(1), t1).expect("full");
        assert_eq!(first.formed_at, t0);
        // next batch starts fresh: its formed_at is t2, not t0 or "now"
        b.push(req(2), t2);
        let second = b.flush().expect("pending");
        assert_eq!(second.formed_at, t2);
    }

    #[test]
    fn empty_batcher_never_flushes_on_poll() {
        let mut b = Batcher::new(BatchOptions::default());
        assert!(b.poll(Instant::now() + Duration::from_secs(1)).is_none());
        assert!(b.deadline().is_none());
        assert!(b.flush().is_none());
    }
}
