//! Dynamic batcher: groups requests into model-sized batches under a
//! latency bound (classic serving tradeoff). Pure state machine —
//! thread plumbing lives in `server.rs` so this is unit-testable.

use super::Request;
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy)]
pub struct BatchOptions {
    /// Flush when this many requests are waiting (= compiled batch).
    pub max_batch: usize,
    /// Flush a non-empty batch this long after its first request.
    pub max_wait: Duration,
}

impl Default for BatchOptions {
    fn default() -> Self {
        BatchOptions { max_batch: 64, max_wait: Duration::from_millis(2) }
    }
}

/// Accumulates requests; `push`/`poll` report when a batch is ready.
pub struct Batcher {
    opts: BatchOptions,
    pending: Vec<Request>,
    oldest: Option<Instant>,
    pub batches_emitted: u64,
    pub requests_seen: u64,
}

impl Batcher {
    pub fn new(opts: BatchOptions) -> Self {
        Batcher {
            opts,
            pending: Vec::new(),
            oldest: None,
            batches_emitted: 0,
            requests_seen: 0,
        }
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Add a request; returns a full batch if this push filled one.
    pub fn push(&mut self, req: Request, now: Instant) -> Option<Vec<Request>> {
        if self.pending.is_empty() {
            self.oldest = Some(now);
        }
        self.pending.push(req);
        self.requests_seen += 1;
        if self.pending.len() >= self.opts.max_batch {
            return Some(self.flush());
        }
        None
    }

    /// Time-based flush check.
    pub fn poll(&mut self, now: Instant) -> Option<Vec<Request>> {
        match self.oldest {
            Some(t0) if !self.pending.is_empty() && now.duration_since(t0) >= self.opts.max_wait => {
                Some(self.flush())
            }
            _ => None,
        }
    }

    /// Deadline for the next time-based flush (for channel timeouts).
    pub fn deadline(&self) -> Option<Instant> {
        self.oldest.map(|t0| t0 + self.opts.max_wait)
    }

    /// Arrival time of the oldest pending request — the start of the
    /// forming batch (`None` when empty). `flush` resets it, so callers
    /// tracing a `batch_form` span must read it before flushing.
    pub fn oldest(&self) -> Option<Instant> {
        self.oldest
    }

    pub fn flush(&mut self) -> Vec<Request> {
        self.oldest = None;
        self.batches_emitted += 1;
        std::mem::take(&mut self.pending)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request { id, lookups: vec![vec![1]], dense: vec![0.0] }
    }

    #[test]
    fn flushes_on_size() {
        let mut b = Batcher::new(BatchOptions { max_batch: 3, max_wait: Duration::from_secs(10) });
        let t = Instant::now();
        assert!(b.push(req(0), t).is_none());
        assert!(b.push(req(1), t).is_none());
        let batch = b.push(req(2), t).expect("full");
        assert_eq!(batch.len(), 3);
        assert_eq!(b.pending(), 0);
        assert_eq!(b.batches_emitted, 1);
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b = Batcher::new(BatchOptions { max_batch: 100, max_wait: Duration::from_millis(5) });
        let t0 = Instant::now();
        b.push(req(0), t0);
        assert!(b.poll(t0 + Duration::from_millis(1)).is_none());
        let batch = b.poll(t0 + Duration::from_millis(6)).expect("deadline");
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn every_request_in_exactly_one_batch() {
        let mut b = Batcher::new(BatchOptions { max_batch: 4, max_wait: Duration::from_millis(1) });
        let t0 = Instant::now();
        let mut seen = Vec::new();
        for i in 0..10 {
            if let Some(batch) = b.push(req(i), t0) {
                seen.extend(batch.iter().map(|r| r.id));
            }
        }
        if let Some(batch) = b.poll(t0 + Duration::from_millis(2)) {
            seen.extend(batch.iter().map(|r| r.id));
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn oldest_tracks_first_arrival_and_resets_on_flush() {
        let mut b = Batcher::new(BatchOptions { max_batch: 3, max_wait: Duration::from_secs(10) });
        assert!(b.oldest().is_none());
        let t0 = Instant::now();
        b.push(req(0), t0);
        b.push(req(1), t0 + Duration::from_millis(1));
        assert_eq!(b.oldest(), Some(t0));
        b.flush();
        assert!(b.oldest().is_none());
    }

    #[test]
    fn empty_batcher_never_flushes_on_poll() {
        let mut b = Batcher::new(BatchOptions::default());
        assert!(b.poll(Instant::now() + Duration::from_secs(1)).is_none());
        assert!(b.deadline().is_none());
    }
}
