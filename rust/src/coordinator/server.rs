//! Serving loop: one worker thread owns the model + PJRT runtime (the
//! xla client is not Sync) and drains a request channel through the
//! batcher. Callers get responses over per-request channels.

use super::batcher::{BatchOptions, Batcher};
use super::{DlrmModel, Request, Response};
use crate::error::{EmberError, Result};
use crate::runtime::Runtime;
use std::path::PathBuf;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

type Envelope = (Request, Sender<Result<Response>>);

/// Serving statistics (snapshot via `stats`).
#[derive(Debug, Default, Clone, Copy)]
pub struct ServeStats {
    pub requests: u64,
    pub batches: u64,
    pub errors: u64,
}

/// A running DLRM coordinator.
pub struct Coordinator {
    tx: Option<Sender<Envelope>>,
    handle: Option<JoinHandle<ServeStats>>,
}

impl Coordinator {
    /// Spawn the worker. The PJRT client is not `Send`, so the worker
    /// constructs its own `Runtime` from `artifacts_dir`; `None` uses
    /// the pure-Rust MLP (useful where PJRT is unavailable).
    pub fn start(model: DlrmModel, artifacts_dir: Option<PathBuf>, opts: BatchOptions) -> Self {
        let (tx, rx) = mpsc::channel::<Envelope>();
        let handle = std::thread::spawn(move || {
            let runtime = artifacts_dir.and_then(|d| Runtime::new(d).ok());
            worker(model, runtime, opts, rx)
        });
        Coordinator { tx: Some(tx), handle: Some(handle) }
    }

    /// Async submit: returns the response channel.
    pub fn submit(&self, req: Request) -> Result<Receiver<Result<Response>>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .as_ref()
            .ok_or_else(|| EmberError::Runtime("coordinator stopped".into()))?
            .send((req, rtx))
            .map_err(|_| EmberError::Runtime("coordinator worker gone".into()))?;
        Ok(rrx)
    }

    /// Sync convenience: submit + wait.
    pub fn infer(&self, req: Request) -> Result<Response> {
        let rx = self.submit(req)?;
        rx.recv()
            .map_err(|_| EmberError::Runtime("worker dropped response".into()))?
    }

    /// Stop the worker and return its stats.
    pub fn shutdown(mut self) -> ServeStats {
        drop(self.tx.take());
        self.handle
            .take()
            .map(|h| h.join().unwrap_or_default())
            .unwrap_or_default()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn worker(
    model: DlrmModel,
    mut runtime: Option<Runtime>,
    opts: BatchOptions,
    rx: Receiver<Envelope>,
) -> ServeStats {
    let mut stats = ServeStats::default();
    let mut batcher = Batcher::new(opts);
    let mut waiting: Vec<Sender<Result<Response>>> = Vec::new();
    let mut inflight: Vec<Vec<Sender<Result<Response>>>> = Vec::new();

    let mut run_batch = |model: &DlrmModel,
                         runtime: &mut Option<Runtime>,
                         batch: Vec<Request>,
                         senders: Vec<Sender<Result<Response>>>,
                         stats: &mut ServeStats| {
        stats.batches += 1;
        let result = match runtime {
            Some(rt) => model.infer_batch(rt, &batch),
            None => model.infer_batch_cpu(&batch),
        };
        match result {
            Ok(responses) => {
                for (resp, tx) in responses.into_iter().zip(senders) {
                    let _ = tx.send(Ok(resp));
                }
            }
            Err(e) => {
                stats.errors += 1;
                let msg = e.to_string();
                for tx in senders {
                    let _ = tx.send(Err(EmberError::Runtime(msg.clone())));
                }
            }
        }
    };

    loop {
        // wait for work, bounded by the batcher's flush deadline
        let timeout = batcher
            .deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(std::time::Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok((req, rtx)) => {
                stats.requests += 1;
                waiting.push(rtx);
                if let Some(batch) = batcher.push(req, Instant::now()) {
                    let senders = std::mem::take(&mut waiting);
                    inflight.push(Vec::new());
                    run_batch(&model, &mut runtime, batch, senders, &mut stats);
                    inflight.pop();
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if let Some(batch) = batcher.poll(Instant::now()) {
                    let senders = std::mem::take(&mut waiting);
                    run_batch(&model, &mut runtime, batch, senders, &mut stats);
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                // drain the final partial batch
                let batch = batcher.flush();
                if !batch.is_empty() {
                    let senders = std::mem::take(&mut waiting);
                    run_batch(&model, &mut runtime, batch, senders, &mut stats);
                }
                break;
            }
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::time::Duration;

    fn tiny() -> DlrmModel {
        DlrmModel::new(4, 64, 8, 2, 6, 3, 16, 42).unwrap()
    }

    fn req(id: u64, rng: &mut Rng, m: &DlrmModel) -> Request {
        Request {
            id,
            lookups: (0..m.num_tables)
                .map(|_| (0..4).map(|_| rng.below(m.table_rows as u64) as i32).collect())
                .collect(),
            dense: (0..m.dense).map(|_| rng.f32()).collect(),
        }
    }

    #[test]
    fn serves_and_matches_direct_inference() {
        let m = tiny();
        let mut rng = Rng::new(9);
        let reqs: Vec<Request> = (0..8).map(|i| req(i, &mut rng, &m)).collect();
        let direct: Vec<Response> = reqs
            .chunks(4)
            .flat_map(|c| tiny().infer_batch_cpu(c).unwrap())
            .collect();

        let coord = Coordinator::start(
            tiny(),
            None,
            BatchOptions { max_batch: 4, max_wait: Duration::from_millis(1) },
        );
        let rxs: Vec<_> = reqs.iter().map(|r| coord.submit(r.clone()).unwrap()).collect();
        let mut got: Vec<Response> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
        got.sort_by_key(|r| r.id);
        let stats = coord.shutdown();
        assert_eq!(stats.requests, 8);
        assert!(stats.batches >= 2);
        for (g, d) in got.iter().zip(&direct) {
            assert_eq!(g.id, d.id);
            assert!((g.score - d.score).abs() < 1e-6);
        }
    }

    #[test]
    fn partial_batch_flushes_on_shutdown_or_timer() {
        let m = tiny();
        let mut rng = Rng::new(10);
        let coord = Coordinator::start(
            m,
            None,
            BatchOptions { max_batch: 64, max_wait: Duration::from_millis(1) },
        );
        let m2 = tiny();
        let r = coord.infer(req(1, &mut rng, &m2)).unwrap();
        assert!(r.score > 0.0 && r.score < 1.0);
        coord.shutdown();
    }
}
