//! Serving loop: a coordinator thread owns the model + PJRT runtime
//! (the xla client is not Sync) and drains a request channel through
//! the batcher. The embedding stage optionally fans out to a
//! table-sharded [`ShardPool`]; callers get responses over per-request
//! channels and latency histograms accumulate into [`ServeStats`].
//!
//! Overload path: every submit passes the [`crate::qos`] admission
//! queue (bounded depth + shed policy) before entering the channel;
//! per-request deadlines ride the envelope so expired work is shed
//! again at batch formation and propagated to the embedding stage,
//! which can stop wasting shard round-trips on a dead batch.

use super::batcher::{Batch, BatchOptions, Batcher};
use super::shard::ShardPool;
use super::stats::ServeStats;
use super::{DlrmModel, EmbedOutcome, EmbedStage, Request, Response};
use crate::error::{EmberError, Result};
use crate::qos::{AdmissionQueue, Controller, QosOptions, ShedPolicy};
use crate::runtime::Runtime;
use crate::trace::{current_tid, TraceEvent, TraceSink};
use std::path::PathBuf;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// (request, submit time, deadline, response channel)
type Envelope = (Request, Instant, Option<Instant>, Sender<Result<Response>>);

/// Per-request bookkeeping the worker keeps alongside the batcher:
/// submit time, deadline, response channel — index-aligned with the
/// batcher's pending queue.
type Waiting = (Instant, Option<Instant>, Sender<Result<Response>>);

/// Full serving configuration: batching + embedding-stage parallelism
/// + admission control.
#[derive(Debug, Clone, Copy)]
pub struct ServeOptions {
    pub batch: BatchOptions,
    /// Embedding shard workers. `1` keeps the embedding stage on the
    /// coordinator thread (the classic single-worker path); `n > 1`
    /// spawns a [`ShardPool`] partitioning tables across `n` threads.
    pub shards: usize,
    /// Admission control / overload shedding. The default (unbounded
    /// queue, policy `none`) reproduces the pre-QoS behavior exactly.
    pub qos: QosOptions,
    /// Intra-batch kernel threads per shard worker (fast-path output
    /// rows split across a scoped pool). `1` keeps each shard's
    /// kernels serial; higher counts stay byte-identical.
    pub threads: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            batch: BatchOptions::default(),
            shards: 1,
            qos: QosOptions::default(),
            threads: 1,
        }
    }
}

/// A running DLRM coordinator.
pub struct Coordinator {
    tx: Option<Sender<Envelope>>,
    ctrl: Arc<Controller>,
    handle: Option<JoinHandle<ServeStats>>,
    trace: TraceSink,
}

/// Cloneable submit handle. Client threads each take their own handle
/// (a cheap `Sender` clone), so load generators never have to borrow
/// the `Coordinator` itself — whose `shutdown(self)` needs sole
/// ownership — across threads. Every submit passes admission control;
/// rejected requests get [`EmberError::Overloaded`] immediately, with
/// no envelope ever entering the channel.
#[derive(Clone)]
pub struct CoordinatorClient {
    queue: AdmissionQueue<Envelope>,
    trace: TraceSink,
}

impl CoordinatorClient {
    /// Async submit: returns the response channel.
    pub fn submit(&self, req: Request) -> Result<Receiver<Result<Response>>> {
        self.submit_with_deadline(req, None)
    }

    /// Async submit with an absolute deadline. The deadline rides with
    /// the request: admission may refuse it outright (queue full /
    /// unmeetable), batch formation sheds it if it expires while
    /// queued, and the embedding stage forwards the remaining budget to
    /// shard servers. A response delivered after the deadline still
    /// arrives but is counted in `ServeStats::deadline_missed`.
    pub fn submit_with_deadline(
        &self,
        req: Request,
        deadline: Option<Instant>,
    ) -> Result<Receiver<Result<Response>>> {
        let (rtx, rrx) = mpsc::channel();
        let t0 = Instant::now();
        let id = req.id;
        self.queue.try_send((req, t0, deadline, rtx), t0, deadline)?;
        if self.trace.is_enabled() {
            // flow arrow from the submitting thread to the worker's
            // dequeue, correlated by request id (recorded only for
            // admitted requests — shed ones never reach the worker)
            let tid = self.trace.name_current_thread("client");
            self.trace.record(TraceEvent::flow_start("req", id, tid, self.trace.ts_of(t0)));
        }
        Ok(rrx)
    }

    /// Sync convenience: submit + wait.
    pub fn infer(&self, req: Request) -> Result<Response> {
        self.infer_with_deadline(req, None)
    }

    /// Sync submit-with-deadline + wait.
    pub fn infer_with_deadline(&self, req: Request, deadline: Option<Instant>) -> Result<Response> {
        let rx = self.submit_with_deadline(req, deadline)?;
        rx.recv()
            .map_err(|_| EmberError::Runtime("worker dropped response".into()))?
    }
}

impl Coordinator {
    /// Spawn a single-worker coordinator (embedding stage inline on the
    /// coordinator thread). The PJRT client is not `Send`, so the
    /// worker constructs its own `Runtime` from `artifacts_dir`; `None`
    /// uses the pure-Rust MLP (useful where PJRT is unavailable).
    pub fn start(model: DlrmModel, artifacts_dir: Option<PathBuf>, opts: BatchOptions) -> Self {
        Self::start_sharded(
            model,
            artifacts_dir,
            ServeOptions { batch: opts, ..Default::default() },
        )
    }

    /// Spawn a coordinator whose embedding stage is sharded by table
    /// across `opts.shards` worker threads.
    ///
    /// `max_batch` is clamped to the model's compiled batch: a full
    /// batch larger than the program's batch dimension would make every
    /// request in it fail, so the batcher is never allowed to form one.
    pub fn start_sharded(
        model: DlrmModel,
        artifacts_dir: Option<PathBuf>,
        opts: ServeOptions,
    ) -> Self {
        Self::start_sharded_traced(model, artifacts_dir, opts, TraceSink::disabled())
    }

    /// [`Coordinator::start_sharded`] with a trace sink: the worker,
    /// shard pool and every [`CoordinatorClient`] emit request-
    /// lifecycle spans and flow events into `trace`.
    pub fn start_sharded_traced(
        model: DlrmModel,
        artifacts_dir: Option<PathBuf>,
        mut opts: ServeOptions,
        trace: TraceSink,
    ) -> Self {
        opts.batch.max_batch = opts.batch.max_batch.clamp(1, model.batch.max(1));
        let ctrl = Arc::new(Controller::new(opts.qos));
        let (tx, rx) = mpsc::channel::<Envelope>();
        let worker_trace = trace.clone();
        let worker_ctrl = ctrl.clone();
        let handle = std::thread::spawn(move || {
            let runtime = artifacts_dir.and_then(|d| Runtime::new(d).ok());
            let embedder: Option<Box<dyn EmbedStage>> = if opts.shards > 1 {
                Some(Box::new(ShardPool::with_options(
                    &model,
                    opts.shards,
                    worker_trace.clone(),
                    crate::exec::ExecOptions::with_threads(opts.threads),
                )))
            } else {
                None
            };
            worker(model, embedder, runtime, opts.batch, rx, worker_ctrl, worker_trace)
        });
        Coordinator { tx: Some(tx), ctrl, handle: Some(handle), trace }
    }

    /// Spawn a coordinator whose embedding stage is delegated to a
    /// caller-supplied [`EmbedStage`] — e.g. a [`crate::net::NetFrontend`]
    /// fanning lookups out to shard-server processes. Scoring stays on
    /// the coordinator thread; per-batch `degraded` counts from the
    /// stage accumulate into [`ServeStats::degraded`].
    pub fn start_with_embedder(
        model: DlrmModel,
        artifacts_dir: Option<PathBuf>,
        opts: ServeOptions,
        embedder: Box<dyn EmbedStage>,
    ) -> Self {
        Self::start_with_embedder_traced(
            model,
            artifacts_dir,
            opts,
            embedder,
            TraceSink::disabled(),
        )
    }

    /// [`Coordinator::start_with_embedder`] with a trace sink attached
    /// to the worker (the embedder keeps whatever sink it was built
    /// with — e.g. a `NetFrontend` sharing this same sink).
    pub fn start_with_embedder_traced(
        model: DlrmModel,
        artifacts_dir: Option<PathBuf>,
        mut opts: ServeOptions,
        embedder: Box<dyn EmbedStage>,
        trace: TraceSink,
    ) -> Self {
        opts.batch.max_batch = opts.batch.max_batch.clamp(1, model.batch.max(1));
        let ctrl = Arc::new(Controller::new(opts.qos));
        let (tx, rx) = mpsc::channel::<Envelope>();
        let worker_trace = trace.clone();
        let worker_ctrl = ctrl.clone();
        let handle = std::thread::spawn(move || {
            let runtime = artifacts_dir.and_then(|d| Runtime::new(d).ok());
            worker(model, Some(embedder), runtime, opts.batch, rx, worker_ctrl, worker_trace)
        });
        Coordinator { tx: Some(tx), ctrl, handle: Some(handle), trace }
    }

    /// A cloneable submit handle for this coordinator.
    pub fn client(&self) -> Result<CoordinatorClient> {
        let tx = self
            .tx
            .as_ref()
            .ok_or_else(|| EmberError::Runtime("coordinator stopped".into()))?
            .clone();
        Ok(CoordinatorClient {
            queue: AdmissionQueue::new(tx, self.ctrl.clone()),
            trace: self.trace.clone(),
        })
    }

    /// Async submit: returns the response channel.
    pub fn submit(&self, req: Request) -> Result<Receiver<Result<Response>>> {
        self.client()?.submit(req)
    }

    /// Sync convenience: submit + wait.
    pub fn infer(&self, req: Request) -> Result<Response> {
        self.client()?.infer(req)
    }

    /// Live QoS counters (queue depth, sheds, queue-delay EWMA).
    pub fn qos_counters(&self) -> crate::qos::QosCounters {
        self.ctrl.counters()
    }

    /// Stop the worker and return its stats.
    pub fn shutdown(mut self) -> ServeStats {
        drop(self.tx.take());
        self.handle
            .take()
            .map(|h| h.join().unwrap_or_default())
            .unwrap_or_default()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Run one flushed batch: embedding (sharded or inline), MLP, then
/// per-request responses + latency recording.
///
/// `formed_at` is when the batch's oldest request arrived — the start
/// of the `batch_form` span when tracing. `deadline` is the batch's
/// collective deadline (see [`batch_deadline`]), forwarded to the
/// embedding stage.
#[allow(clippy::too_many_arguments)]
fn run_batch(
    model: &DlrmModel,
    embedder: &mut Option<Box<dyn EmbedStage>>,
    runtime: &mut Option<Runtime>,
    batch: Vec<Request>,
    senders: Vec<Waiting>,
    stats: &mut ServeStats,
    formed_at: Instant,
    deadline: Option<Instant>,
    trace: &TraceSink,
) {
    stats.batches += 1;
    let tid = if trace.is_enabled() { current_tid() } else { 0 };
    if trace.is_enabled() {
        let ts = trace.ts_of(formed_at);
        trace.record(
            TraceEvent::complete("batch_form", "serve", tid, ts, (trace.now_us() - ts).max(0.0))
                .with_arg("requests", batch.len() as f64),
        );
    }
    // one Arc wrap instead of a per-shard deep copy of the batch
    let batch = Arc::new(batch);
    let embed_t = trace.now_us();
    let outcome = match embedder.as_deref_mut() {
        Some(stage) => stage.embed_stage(&batch, deadline),
        None => model.embed(&batch).map(|e| EmbedOutcome { embeddings: e, degraded: 0 }),
    };
    if trace.is_enabled() {
        let degraded = outcome.as_ref().map(|o| o.degraded).unwrap_or(0);
        trace.record(
            TraceEvent::complete(
                "embed",
                "serve",
                tid,
                embed_t,
                (trace.now_us() - embed_t).max(0.0),
            )
            .with_arg("degraded", degraded as f64),
        );
        // counter tracks for the tiered store, sampled once per batch
        if model.tables.iter().any(|t| t.tiered().is_some()) {
            let st = model.store_stats();
            let ts = trace.now_us();
            trace.record(TraceEvent::counter("store/hot_hit_rate", tid, ts, st.hit_pct()));
            trace.record(TraceEvent::counter(
                "store/resident_bytes",
                tid,
                ts,
                st.resident_bytes as f64,
            ));
        }
    }
    let mlp_t = trace.now_us();
    let result = outcome.and_then(|o| {
        stats.degraded += o.degraded;
        model.score(runtime, &batch, &o.embeddings)
    });
    if trace.is_enabled() {
        trace.record(TraceEvent::complete(
            "mlp",
            "serve",
            tid,
            mlp_t,
            (trace.now_us() - mlp_t).max(0.0),
        ));
    }
    match result {
        Ok(responses) => {
            let done = Instant::now();
            for (resp, (t0, dl, tx)) in responses.into_iter().zip(senders) {
                stats.hist.record(done.duration_since(t0));
                if dl.is_some_and(|d| done > d) {
                    // served, but too late to be useful — delivered
                    // anyway (the caller may still want it), counted
                    stats.deadline_missed += 1;
                }
                if trace.is_enabled() {
                    trace.record(TraceEvent::async_end(
                        "request",
                        "req",
                        resp.id,
                        tid,
                        trace.now_us(),
                    ));
                }
                let _ = tx.send(Ok(resp));
            }
        }
        Err(e) => {
            stats.errors += 1;
            let msg = e.to_string();
            for (i, (t0, _dl, tx)) in senders.into_iter().enumerate() {
                stats.hist.record(t0.elapsed());
                // record() is a no-op on a disabled sink, no guard needed
                if let Some(r) = batch.get(i) {
                    trace.record(TraceEvent::async_end(
                        "request",
                        "req",
                        r.id,
                        tid,
                        trace.now_us(),
                    ));
                }
                let _ = tx.send(Err(EmberError::Runtime(msg.clone())));
            }
        }
    }
}

/// A batch's collective deadline: the latest member deadline, or
/// `None` if any member has no deadline (the batch must then run
/// unconditionally — shedding it would strand an un-deadlined
/// request).
fn batch_deadline(senders: &[Waiting]) -> Option<Instant> {
    let mut latest: Option<Instant> = None;
    for (_, dl, _) in senders {
        match dl {
            None => return None,
            Some(d) => latest = Some(latest.map_or(*d, |l| l.max(*d))),
        }
    }
    latest
}

/// Take a flushed batch through deadline shedding and into
/// [`run_batch`]. Consumes exactly `batch.len()` entries from the
/// front of `waiting` — the batcher may flush a batch that excludes
/// the most recently pushed request (lookup-budget closure), so "take
/// everything" would desync senders from requests.
#[allow(clippy::too_many_arguments)]
fn dispatch_batch(
    model: &DlrmModel,
    embedder: &mut Option<Box<dyn EmbedStage>>,
    runtime: &mut Option<Runtime>,
    batch: Batch,
    waiting: &mut Vec<Waiting>,
    ctrl: &Controller,
    stats: &mut ServeStats,
    trace: &TraceSink,
) {
    let n = batch.reqs.len().min(waiting.len());
    let items: Vec<Waiting> = waiting.drain(..n).collect();
    let Batch { reqs, formed_at } = batch;

    // shed-at-batch-formation: a request whose deadline passed while
    // it sat in the forming batch gets a typed rejection now, before
    // any embedding work — never a shard round-trip for a dead request
    let now = Instant::now();
    let shed_enabled = ctrl.policy() != ShedPolicy::None;
    let mut live_reqs = Vec::with_capacity(n);
    let mut live_senders = Vec::with_capacity(n);
    for (req, (t0, dl, tx)) in reqs.into_iter().zip(items) {
        if shed_enabled && dl.is_some_and(|d| now >= d) {
            stats.shed_batch += 1;
            if trace.is_enabled() {
                // close the request's async span — it ends here
                trace.record(TraceEvent::async_end(
                    "request",
                    "req",
                    req.id,
                    current_tid(),
                    trace.now_us(),
                ));
            }
            let _ = tx.send(Err(EmberError::Overloaded(
                "deadline expired before batch formation".into(),
            )));
        } else {
            live_reqs.push(req);
            live_senders.push((t0, dl, tx));
        }
    }
    if !live_reqs.is_empty() {
        let deadline = batch_deadline(&live_senders);
        run_batch(
            model,
            embedder,
            runtime,
            live_reqs,
            live_senders,
            stats,
            formed_at,
            deadline,
            trace,
        );
    }
    if trace.is_enabled() {
        let qc = ctrl.counters();
        let ts = trace.now_us();
        let tid = current_tid();
        trace.record(TraceEvent::counter("qos/queue_depth", tid, ts, qc.depth as f64));
        trace.record(TraceEvent::counter(
            "qos/shed",
            tid,
            ts,
            (qc.shed_admission + qc.rejected_full + stats.shed_batch) as f64,
        ));
    }
}

fn worker(
    model: DlrmModel,
    mut embedder: Option<Box<dyn EmbedStage>>,
    mut runtime: Option<Runtime>,
    opts: BatchOptions,
    rx: Receiver<Envelope>,
    ctrl: Arc<Controller>,
    trace: TraceSink,
) -> ServeStats {
    let started = Instant::now();
    let mut stats = ServeStats::default();
    let mut batcher = Batcher::new(opts);
    let mut waiting: Vec<Waiting> = Vec::new();
    let worker_tid = if trace.is_enabled() {
        trace.name_current_thread("coordinator worker")
    } else {
        0
    };

    loop {
        // wait for work, bounded by the batcher's flush deadline
        let timeout = batcher
            .deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(std::time::Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok((req, t0, deadline, rtx)) => {
                stats.requests += 1;
                // frees the admission slot + feeds the queue-delay EWMA
                ctrl.on_dequeue(t0.elapsed());
                if trace.is_enabled() {
                    // close the submit-side flow arrow and open the
                    // request's async span at its submit time
                    trace.record(TraceEvent::flow_end("req", req.id, worker_tid, trace.now_us()));
                    trace.record(TraceEvent::async_begin(
                        "request",
                        "req",
                        req.id,
                        worker_tid,
                        trace.ts_of(t0),
                    ));
                }
                waiting.push((t0, deadline, rtx));
                if let Some(batch) = batcher.push(req, Instant::now()) {
                    dispatch_batch(
                        &model,
                        &mut embedder,
                        &mut runtime,
                        batch,
                        &mut waiting,
                        &ctrl,
                        &mut stats,
                        &trace,
                    );
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if let Some(batch) = batcher.poll(Instant::now()) {
                    dispatch_batch(
                        &model,
                        &mut embedder,
                        &mut runtime,
                        batch,
                        &mut waiting,
                        &ctrl,
                        &mut stats,
                        &trace,
                    );
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                // drain the final partial batch
                if let Some(batch) = batcher.flush() {
                    dispatch_batch(
                        &model,
                        &mut embedder,
                        &mut runtime,
                        batch,
                        &mut waiting,
                        &ctrl,
                        &mut stats,
                        &trace,
                    );
                }
                break;
            }
        }
    }
    // the worker's table set is the authoritative store view — shard
    // pool clones share the same Arcs, so this sums every thread's
    // accesses exactly once
    stats.store = model.store_stats();
    // admission-side sheds live in the shared controller (they never
    // reach this thread as envelopes); fold them in at shutdown
    let qc = ctrl.counters();
    stats.shed_admission = qc.shed_admission;
    stats.rejected_full = qc.rejected_full;
    stats.elapsed = started.elapsed();
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::time::Duration;

    fn tiny() -> DlrmModel {
        DlrmModel::new(4, 64, 8, 2, 6, 3, 16, 42).unwrap()
    }

    fn req(id: u64, rng: &mut Rng, m: &DlrmModel) -> Request {
        Request {
            id,
            lookups: (0..m.num_tables)
                .map(|_| (0..4).map(|_| rng.below(m.table_rows as u64) as i32).collect())
                .collect(),
            dense: (0..m.dense).map(|_| rng.f32()).collect(),
        }
    }

    #[test]
    fn serves_and_matches_direct_inference() {
        let m = tiny();
        let mut rng = Rng::new(9);
        let reqs: Vec<Request> = (0..8).map(|i| req(i, &mut rng, &m)).collect();
        let direct: Vec<Response> = reqs
            .chunks(4)
            .flat_map(|c| tiny().infer_batch_cpu(c).unwrap())
            .collect();

        let coord = Coordinator::start(
            tiny(),
            None,
            BatchOptions { max_batch: 4, max_wait: Duration::from_millis(1), ..Default::default() },
        );
        let rxs: Vec<_> = reqs.iter().map(|r| coord.submit(r.clone()).unwrap()).collect();
        let mut got: Vec<Response> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
        got.sort_by_key(|r| r.id);
        let stats = coord.shutdown();
        assert_eq!(stats.requests, 8);
        assert!(stats.batches >= 2);
        assert_eq!(stats.hist.count(), 8, "every response records a latency");
        assert!(!stats.elapsed.is_zero());
        assert_eq!(stats.shed(), 0, "default options never shed");
        for (g, d) in got.iter().zip(&direct) {
            assert_eq!(g.id, d.id);
            assert!((g.score - d.score).abs() < 1e-6);
        }
    }

    #[test]
    fn partial_batch_flushes_on_shutdown_or_timer() {
        let m = tiny();
        let mut rng = Rng::new(10);
        let coord = Coordinator::start(
            m,
            None,
            BatchOptions { max_batch: 64, max_wait: Duration::from_millis(1), ..Default::default() },
        );
        let m2 = tiny();
        let r = coord.infer(req(1, &mut rng, &m2)).unwrap();
        assert!(r.score > 0.0 && r.score < 1.0);
        coord.shutdown();
    }

    #[test]
    fn sharded_coordinator_matches_single_worker() {
        let mut rng = Rng::new(11);
        let m = tiny();
        let reqs: Vec<Request> = (0..12).map(|i| req(i, &mut rng, &m)).collect();
        let run = |shards: usize| -> Vec<Response> {
            let coord = Coordinator::start_sharded(
                tiny(),
                None,
                ServeOptions {
                    batch: BatchOptions {
                        max_batch: 4,
                        max_wait: Duration::from_millis(1),
                        ..Default::default()
                    },
                    shards,
                    ..Default::default()
                },
            );
            let rxs: Vec<_> =
                reqs.iter().map(|r| coord.submit(r.clone()).unwrap()).collect();
            let mut got: Vec<Response> =
                rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
            got.sort_by_key(|r| r.id);
            coord.shutdown();
            got
        };
        let single = run(1);
        let sharded = run(2);
        assert_eq!(single.len(), sharded.len());
        for (a, b) in single.iter().zip(&sharded) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.score, b.score, "sharded embed must be byte-identical");
        }
    }

    #[test]
    fn traced_coordinator_matches_untraced_and_records_lifecycle() {
        use crate::trace::Phase;
        let mut rng = Rng::new(12);
        let m = tiny();
        let reqs: Vec<Request> = (0..8).map(|i| req(i, &mut rng, &m)).collect();
        let run = |trace: TraceSink| -> Vec<Response> {
            let coord = Coordinator::start_sharded_traced(
                tiny(),
                None,
                ServeOptions {
                    batch: BatchOptions {
                        max_batch: 4,
                        max_wait: Duration::from_millis(1),
                        ..Default::default()
                    },
                    shards: 2,
                    ..Default::default()
                },
                trace,
            );
            let client = coord.client().unwrap();
            let rxs: Vec<_> = reqs.iter().map(|r| client.submit(r.clone()).unwrap()).collect();
            let mut got: Vec<Response> =
                rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
            got.sort_by_key(|r| r.id);
            coord.shutdown();
            got
        };
        let plain = run(TraceSink::disabled());
        let sink = TraceSink::enabled();
        let traced = run(sink.clone());
        assert_eq!(plain.len(), traced.len());
        for (a, b) in plain.iter().zip(&traced) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.score, b.score, "tracing must not change outputs");
        }
        let evs = sink.drain();
        let has = |n: &str| evs.iter().any(|e| e.name == n);
        assert!(has("batch_form") && has("embed") && has("mlp"), "lifecycle spans");
        assert!(has("req"), "flow events across threads");
        assert!(has("shard_embed"), "per-shard embed spans");
        assert!(has("qos/queue_depth"), "qos counter track");
        assert!(has("qos/shed"), "qos shed counter track");
        let begins = evs
            .iter()
            .filter(|e| e.name == "request" && matches!(e.ph, Phase::AsyncBegin))
            .count();
        let ends = evs
            .iter()
            .filter(|e| e.name == "request" && matches!(e.ph, Phase::AsyncEnd))
            .count();
        assert_eq!(begins, 8, "every request opens its async span");
        assert_eq!(ends, 8, "every request closes its async span");
        // client, worker and shard threads all got labeled tracks
        let th = sink.threads();
        assert!(th.iter().any(|(_, n)| n == "coordinator worker"));
        assert!(th.iter().any(|(_, n)| n == "client"));
        assert!(th.iter().any(|(_, n)| n.starts_with("shard")));
    }

    #[test]
    fn client_handles_submit_from_many_threads() {
        let coord = Coordinator::start(
            tiny(),
            None,
            BatchOptions { max_batch: 4, max_wait: Duration::from_millis(1), ..Default::default() },
        );
        let m = tiny();
        std::thread::scope(|s| {
            for c in 0..4u64 {
                let client = coord.client().unwrap();
                let m = &m;
                s.spawn(move || {
                    let mut rng = Rng::new(100 + c);
                    for k in 0..8u64 {
                        let r = client.infer(req(c * 100 + k, &mut rng, m)).unwrap();
                        assert!(r.score > 0.0 && r.score < 1.0);
                    }
                });
            }
        });
        let stats = coord.shutdown();
        assert_eq!(stats.requests, 32);
        assert_eq!(stats.hist.count(), 32);
    }

    /// Deterministic shed-at-batch-formation: the request's deadline is
    /// valid at admission (EWMA is zero) but expires long before the
    /// 20ms batch timer fires, so the flush must shed it with the typed
    /// `Overloaded` error — never serve it, never call it a failure.
    #[test]
    fn batch_formation_sheds_expired_requests_with_typed_error() {
        let mut rng = Rng::new(13);
        let m = tiny();
        let coord = Coordinator::start_sharded(
            tiny(),
            None,
            ServeOptions {
                batch: BatchOptions {
                    max_batch: 64,
                    max_wait: Duration::from_millis(20),
                    ..Default::default()
                },
                shards: 1,
                qos: QosOptions { queue_depth: 0, policy: ShedPolicy::Deadline },
                threads: 1,
            },
        );
        let client = coord.client().unwrap();
        let r = req(1, &mut rng, &m);
        let rx = client
            .submit_with_deadline(r, Some(Instant::now() + Duration::from_millis(2)))
            .expect("admission must pass while the EWMA is zero");
        let got = rx.recv().expect("worker must answer shed requests");
        match got {
            Err(EmberError::Overloaded(_)) => {}
            other => panic!("expected Overloaded, got {other:?}"),
        }
        let stats = coord.shutdown();
        assert_eq!(stats.shed_batch, 1);
        assert_eq!(stats.errors, 0, "a shed is not a failure");
        assert_eq!(stats.hist.count(), 0, "shed requests record no service latency");
    }

    /// With policy `none`, deadlines are carried but never enforced:
    /// the same expired-deadline request is served normally and only
    /// the observability counter moves.
    #[test]
    fn policy_none_serves_expired_deadlines_and_counts_misses() {
        let mut rng = Rng::new(14);
        let m = tiny();
        let coord = Coordinator::start(
            tiny(),
            None,
            BatchOptions { max_batch: 4, max_wait: Duration::from_millis(1), ..Default::default() },
        );
        let client = coord.client().unwrap();
        let r = client
            .infer_with_deadline(req(1, &mut rng, &m), Some(Instant::now()))
            .expect("policy none must serve expired requests");
        assert!(r.score > 0.0 && r.score < 1.0);
        let stats = coord.shutdown();
        assert_eq!(stats.shed(), 0);
        assert_eq!(stats.deadline_missed, 1);
    }
}
