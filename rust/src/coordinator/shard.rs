//! Table-sharded embedding worker pool.
//!
//! The embedding stage of a DLRM batch is embarrassingly parallel
//! across tables, and it is where the serving loop used to burn its
//! time: one interpreter construction, one CSR allocation and one full
//! table-tensor clone *per table per batch*. The pool fixes both axes:
//!
//!   * **parallelism** — tables are partitioned round-robin across
//!     shard threads; each shard runs its tables' lookups concurrently
//!     with every other shard and the merge is a cheap row-slice copy;
//!   * **hot-path allocation** — each shard owns a pooled executor
//!     [`Instance`] on the compiled fast path ([`Backend::Fast`]: the
//!     SLS gather runs as a fused flat kernel, byte-identical to the
//!     interpreter) and one pre-bound [`Bindings`] per owned table whose
//!     table tensor is moved in exactly once at pool construction
//!     ([`Bindings::sls_pooled`]). Per batch only the small
//!     `ptrs`/`idxs`/`out` operands are refilled in place
//!     ([`Bindings::refill_csr`]).
//!
//! Numerics: the sharded path performs the identical per-table float
//! operations in the identical order as the sequential
//! [`DlrmModel::embed`], so outputs are byte-identical (asserted by
//! `tests/serving.rs`).

use super::{DlrmModel, Request};
use crate::compiler::passes::pipeline::CompiledProgram;
use crate::error::{EmberError, Result};
use crate::exec::{Backend, Bindings, ExecOptions, Executor, Instance};
use crate::trace::{TraceEvent, TraceSink};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Partition table indices round-robin across `shards` workers.
/// Degenerate inputs clamp: at least one shard, at most one per table.
pub fn shard_plan(num_tables: usize, shards: usize) -> Vec<Vec<usize>> {
    let n = shards.max(1).min(num_tables.max(1));
    let mut plan = vec![Vec::new(); n];
    for t in 0..num_tables {
        plan[t % n].push(t);
    }
    plan
}

/// Per-table embedding output: `(table index, [batch, emb] row-major)`.
type TableOut = (usize, Vec<f32>);

struct Job {
    reqs: Arc<Vec<Request>>,
    reply: Sender<Result<Vec<TableOut>>>,
}

/// A pool of persistent shard threads running the embedding stage.
pub struct ShardPool {
    txs: Vec<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
    batch: usize,
    emb: usize,
    num_tables: usize,
}

impl ShardPool {
    /// Spawn `shards` workers, each owning a pooled [`Instance`] for
    /// `model.program` plus pre-bound [`Bindings`] for its tables.
    pub fn new(model: &DlrmModel, shards: usize) -> Self {
        Self::with_trace(model, shards, TraceSink::disabled())
    }

    /// [`ShardPool::new`] with a trace sink: each shard thread records
    /// a `shard_embed` span per batch on its own labeled track.
    pub fn with_trace(model: &DlrmModel, shards: usize, trace: TraceSink) -> Self {
        Self::with_options(model, shards, trace, ExecOptions::default())
    }

    /// [`ShardPool::with_trace`] with explicit [`ExecOptions`]: each
    /// shard's fast-path instance splits output rows across
    /// `exec_opts.threads` scoped workers (byte-identical at every
    /// setting; threads own disjoint rows).
    pub fn with_options(
        model: &DlrmModel,
        shards: usize,
        trace: TraceSink,
        exec_opts: ExecOptions,
    ) -> Self {
        let plan = shard_plan(model.num_tables, shards);
        let mut txs = Vec::with_capacity(plan.len());
        let mut handles = Vec::with_capacity(plan.len());
        for (shard_id, owned) in plan.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<Job>();
            let worker = ShardWorker {
                program: model.program.clone(),
                tables: owned.iter().map(|&t| (t, model.tables[t].clone())).collect(),
                batch: model.batch,
                max_lookups: model.max_lookups,
                shard_id,
                exec_opts,
                trace: trace.clone(),
            };
            handles.push(std::thread::spawn(move || worker.run(rx)));
            txs.push(tx);
        }
        ShardPool {
            txs,
            handles,
            batch: model.batch,
            emb: model.emb,
            num_tables: model.num_tables,
        }
    }

    pub fn num_shards(&self) -> usize {
        self.txs.len()
    }

    /// Run the embedding stage sharded by table. Same contract as
    /// [`DlrmModel::embed`]: `[batch, tables*emb]` row-major, absent
    /// requests padded with zero rows.
    pub fn embed(&self, requests: &[Request]) -> Result<Vec<f32>> {
        self.embed_shared(Arc::new(requests.to_vec()))
    }

    /// Copy-free variant for the serving hot path: the coordinator
    /// wraps its flushed batch in an `Arc` once and every shard reads
    /// it in place.
    pub fn embed_shared(&self, reqs: Arc<Vec<Request>>) -> Result<Vec<f32>> {
        let (rtx, rrx) = mpsc::channel::<Result<Vec<TableOut>>>();
        for tx in &self.txs {
            tx.send(Job { reqs: reqs.clone(), reply: rtx.clone() })
                .map_err(|_| EmberError::Runtime("embedding shard worker gone".into()))?;
        }
        drop(rtx);
        let (b, emb, width) = (self.batch, self.emb, self.num_tables * self.emb);
        let mut out = vec![0f32; b * width];
        let mut failure: Option<EmberError> = None;
        for _ in 0..self.txs.len() {
            let parts = rrx
                .recv()
                .map_err(|_| EmberError::Runtime("embedding shard dropped its reply".into()))?;
            match parts {
                Ok(parts) => {
                    for (t, table_out) in parts {
                        for i in 0..b {
                            let dst = i * width + t * emb;
                            out[dst..dst + emb]
                                .copy_from_slice(&table_out[i * emb..(i + 1) * emb]);
                        }
                    }
                }
                Err(e) => failure = Some(e),
            }
        }
        match failure {
            Some(e) => Err(e),
            None => Ok(out),
        }
    }
}

impl super::EmbedStage for ShardPool {
    /// In-process shards share the coordinator's fate — there is no
    /// partial-failure mode, so `degraded` is always zero and any shard
    /// error fails the whole batch (the pre-net behavior, unchanged).
    /// Deadlines are not enforced here: local embedding is microseconds
    /// of work, so abandoning it mid-batch would only cost determinism.
    fn embed_stage(
        &mut self,
        reqs: &Arc<Vec<Request>>,
        _deadline: Option<std::time::Instant>,
    ) -> Result<super::EmbedOutcome> {
        Ok(super::EmbedOutcome { embeddings: self.embed_shared(reqs.clone())?, degraded: 0 })
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        // disconnect job channels so workers fall out of their recv loop
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// State owned by one shard thread.
struct ShardWorker {
    program: Arc<CompiledProgram>,
    /// `(table index, table store)` — cloned once at pool build; a
    /// tiered store clone is an Arc share, so every worker reads (and
    /// counts into) the same hot tier as the owning model.
    tables: Vec<(usize, crate::store::EmbeddingStore)>,
    batch: usize,
    max_lookups: usize,
    shard_id: usize,
    exec_opts: ExecOptions,
    trace: TraceSink,
}

impl ShardWorker {
    fn run(self, rx: Receiver<Job>) {
        let ShardWorker { program, tables, batch, max_lookups, shard_id, exec_opts, trace } =
            self;
        let tid = if trace.is_enabled() {
            trace.name_current_thread(&format!("shard {shard_id}"))
        } else {
            0
        };
        let mut exec = match Instance::with_options(&program, Backend::Fast, exec_opts) {
            Ok(i) => i,
            Err(e) => {
                // poison every job with the construction error
                let msg = e.to_string();
                while let Ok(job) = rx.recv() {
                    let _ = job.reply.send(Err(EmberError::Runtime(msg.clone())));
                }
                return;
            }
        };
        // one pre-bound binding set per owned table: a dense table
        // tensor is moved in (the pool-build clone is the only copy)
        // and bound exactly once; a tiered store stays shared and its
        // rows are staged per run. ptrs/out are fixed-size and
        // refilled in place either way.
        let mut bindings: Vec<(usize, Bindings)> = tables
            .into_iter()
            .map(|(t, store)| {
                let b = match store {
                    crate::store::EmbeddingStore::Dense(tensor) => {
                        Bindings::sls_pooled(tensor, batch)
                    }
                    store => Bindings::sls_store(&store, batch),
                };
                (t, b)
            })
            .collect();
        let mut ptr_scratch: Vec<i32> = vec![0; batch + 1];
        let mut idx_scratch: Vec<i32> = Vec::new();
        while let Ok(job) = rx.recv() {
            let t_start = trace.now_us();
            let mut parts = Vec::with_capacity(bindings.len());
            let mut failure: Option<EmberError> = None;
            for (t, b) in &mut bindings {
                match run_table(
                    &mut exec,
                    b,
                    *t,
                    &job.reqs,
                    batch,
                    max_lookups,
                    &mut ptr_scratch,
                    &mut idx_scratch,
                ) {
                    Ok(v) => parts.push((*t, v)),
                    Err(e) => {
                        failure = Some(e);
                        break;
                    }
                }
            }
            if trace.is_enabled() {
                trace.record(
                    TraceEvent::complete(
                        "shard_embed",
                        "serve",
                        tid,
                        t_start,
                        (trace.now_us() - t_start).max(0.0),
                    )
                    .with_arg("tables", bindings.len() as f64),
                );
            }
            let reply = match failure {
                Some(e) => Err(e),
                None => Ok(parts),
            };
            let _ = job.reply.send(reply);
        }
    }
}

/// Refill `bindings`' CSR operands for table `t` from the batch, run
/// the pooled executor, and return the `[batch, emb]` output rows.
#[allow(clippy::too_many_arguments)]
fn run_table(
    exec: &mut Instance,
    bindings: &mut Bindings,
    t: usize,
    reqs: &[Request],
    batch: usize,
    max_lookups: usize,
    ptr_scratch: &mut [i32],
    idx_scratch: &mut Vec<i32>,
) -> Result<Vec<f32>> {
    idx_scratch.clear();
    ptr_scratch[0] = 0;
    for i in 0..batch {
        if let Some(l) = reqs.get(i).and_then(|r| r.lookups.get(t)) {
            idx_scratch.extend(l.iter().take(max_lookups));
        }
        ptr_scratch[i + 1] = idx_scratch.len() as i32;
    }
    bindings.refill_csr(ptr_scratch, idx_scratch)?;
    Ok(exec.run(bindings)?.output)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn model(tables: usize) -> DlrmModel {
        DlrmModel::new(4, 64, 8, tables, 6, 3, 16, 42).unwrap()
    }

    fn reqs(m: &DlrmModel, n: usize, seed: u64) -> Vec<Request> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|i| Request {
                id: i as u64,
                lookups: (0..m.num_tables)
                    .map(|_| {
                        (0..1 + rng.below(8) as usize)
                            .map(|_| rng.below(m.table_rows as u64) as i32)
                            .collect()
                    })
                    .collect(),
                dense: (0..m.dense).map(|_| rng.f32()).collect(),
            })
            .collect()
    }

    #[test]
    fn shard_plan_covers_every_table_once() {
        for (tables, shards) in [(16, 4), (5, 2), (3, 8), (1, 1), (0, 3)] {
            let plan = shard_plan(tables, shards);
            assert!(!plan.is_empty());
            assert!(plan.len() <= shards.max(1));
            let mut seen: Vec<usize> = plan.into_iter().flatten().collect();
            seen.sort_unstable();
            assert_eq!(seen, (0..tables).collect::<Vec<_>>(), "{tables}/{shards}");
        }
    }

    #[test]
    fn sharded_embed_is_byte_identical_to_sequential() {
        let m = model(6);
        let pool = ShardPool::new(&m, 3);
        assert_eq!(pool.num_shards(), 3);
        for seed in [1u64, 2, 3] {
            let rs = reqs(&m, 3, seed); // partial batch: padded rows stay zero
            let seq = m.embed(&rs).unwrap();
            let sharded = pool.embed(&rs).unwrap();
            assert_eq!(seq, sharded, "seed {seed}");
        }
    }

    #[test]
    fn pool_survives_reuse_and_oversubscribed_shards() {
        let m = model(2);
        // more shards than tables clamps to one table per shard
        let pool = ShardPool::new(&m, 8);
        assert_eq!(pool.num_shards(), 2);
        let rs = reqs(&m, 4, 9);
        let a = pool.embed(&rs).unwrap();
        let b = pool.embed(&rs).unwrap();
        assert_eq!(a, b);
        assert_eq!(a, m.embed(&rs).unwrap());
    }

    #[test]
    fn empty_batch_embeds_to_zeros() {
        let m = model(2);
        let pool = ShardPool::new(&m, 2);
        let out = pool.embed(&[]).unwrap();
        assert_eq!(out.len(), m.batch * m.num_tables * m.emb);
        assert!(out.iter().all(|&v| v == 0.0));
    }
}
