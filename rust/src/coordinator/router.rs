//! Request router: dispatches requests to named model coordinators
//! (the vllm-router-shaped front door; one `Coordinator` per model).

use super::server::Coordinator;
use super::{Request, Response};
use crate::error::{EmberError, Result};
use std::collections::HashMap;

#[derive(Default)]
pub struct Router {
    models: HashMap<String, Coordinator>,
    /// Round-robin replica groups: model -> replica names.
    replicas: HashMap<String, Vec<String>>,
    rr: HashMap<String, usize>,
}

impl Router {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a coordinator under `name`. Registering several
    /// replicas as `name#k` + `add_replica_group` round-robins them.
    pub fn register(&mut self, name: &str, coord: Coordinator) {
        self.models.insert(name.to_string(), coord);
    }

    pub fn add_replica_group(&mut self, name: &str, members: Vec<String>) {
        self.replicas.insert(name.to_string(), members);
    }

    /// Spread one model across several serving pools: register each
    /// coordinator as a `name#k` replica and round-robin requests for
    /// `name` across them. One call replaces the register +
    /// `add_replica_group` dance per pool.
    pub fn register_pool(&mut self, name: &str, pools: Vec<Coordinator>) {
        let mut members = Vec::with_capacity(pools.len());
        for (k, coord) in pools.into_iter().enumerate() {
            let member = format!("{name}#{k}");
            self.register(&member, coord);
            members.push(member);
        }
        self.add_replica_group(name, members);
    }

    pub fn models(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }

    fn resolve(&mut self, model: &str) -> Result<&Coordinator> {
        let target = if let Some(group) = self.replicas.get(model) {
            if group.is_empty() {
                return Err(EmberError::Runtime(format!("empty replica group `{model}`")));
            }
            let k = self.rr.entry(model.to_string()).or_insert(0);
            let t = group[*k % group.len()].clone();
            *k += 1;
            t
        } else {
            model.to_string()
        };
        self.models
            .get(&target)
            .ok_or_else(|| EmberError::Runtime(format!("unknown model `{target}`")))
    }

    /// Route one request synchronously.
    pub fn infer(&mut self, model: &str, req: Request) -> Result<Response> {
        self.resolve(model)?.infer(req)
    }

    /// Shut everything down.
    pub fn shutdown(self) {
        for (_, c) in self.models {
            c.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatchOptions, DlrmModel};
    use std::time::Duration;

    fn tiny_coord() -> Coordinator {
        Coordinator::start(
            DlrmModel::new(4, 64, 8, 1, 6, 3, 16, 1).unwrap(),
            None,
            BatchOptions { max_batch: 2, max_wait: Duration::from_millis(1), ..Default::default() },
        )
    }

    #[test]
    fn routes_by_name_and_rejects_unknown() {
        let mut r = Router::new();
        r.register("dlrm", tiny_coord());
        let req = Request { id: 1, lookups: vec![vec![3, 4]], dense: vec![0.1, 0.2, 0.3] };
        assert!(r.infer("dlrm", req.clone()).is_ok());
        assert!(r.infer("nope", req).is_err());
        r.shutdown();
    }

    #[test]
    fn register_pool_spreads_requests_round_robin() {
        let mut r = Router::new();
        r.register_pool("dlrm", vec![tiny_coord(), tiny_coord(), tiny_coord()]);
        assert_eq!(r.models().len(), 3);
        let req = Request { id: 1, lookups: vec![vec![2, 5]], dense: vec![0.0; 3] };
        let scores: Vec<f32> =
            (0..6).map(|_| r.infer("dlrm", req.clone()).unwrap().score).collect();
        // same seed on every pool => identical scores through every replica
        for s in &scores {
            assert!((s - scores[0]).abs() < 1e-6);
        }
        r.shutdown();
    }

    #[test]
    fn round_robins_replicas() {
        let mut r = Router::new();
        r.register("dlrm#0", tiny_coord());
        r.register("dlrm#1", tiny_coord());
        r.add_replica_group("dlrm", vec!["dlrm#0".into(), "dlrm#1".into()]);
        let req = Request { id: 1, lookups: vec![vec![3]], dense: vec![0.0; 3] };
        for _ in 0..4 {
            assert!(r.infer("dlrm", req.clone()).is_ok());
        }
        r.shutdown();
    }
}
