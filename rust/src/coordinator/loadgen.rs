//! Load generators for the serving engine.
//!
//! **Closed loop** ([`run_closed_loop`]): `clients` threads each own a
//! cloned [`CoordinatorClient`] and issue requests back-to-back, each
//! waiting for its response before the next submit. With a
//! `target_qps` each client paces its submissions so the coordinator
//! sees an aggregate arrival rate of ~`target_qps`; sweeping the
//! target and plotting [`LoadReport::throughput_rps`] against the
//! report's latency quantiles gives the latency/throughput curve.
//!
//! **Open loop** ([`run_open_loop`]): arrivals follow a Poisson
//! process at `target_qps` regardless of how fast responses come back,
//! so a saturated server accumulates queueing delay instead of
//! silently back-pressuring the generator (the coordinated-omission
//! artifact every closed loop has). This is the mode that can drive
//! the system *past* saturation.
//!
//! Lookup indices are drawn [`IndexDist::Uniform`] or
//! [`IndexDist::Zipf`] — production embedding traffic is heavily
//! skewed, and skew is what makes hot-table replication matter.

use super::server::Coordinator;
use super::stats::LatencyHist;
use super::{Request, Response};
use crate::error::{EmberError, Result};
use crate::util::rng::{Rng, Zipf};
use std::fmt;
use std::sync::mpsc::{self, Receiver};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Which distribution lookup indices are drawn from.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum IndexDist {
    /// Every table row equally likely.
    #[default]
    Uniform,
    /// Zipf with exponent `s` over row ranks (row 0 hottest) — the
    /// shape real embedding-access traces follow.
    Zipf(f64),
}

impl IndexDist {
    /// Validated [`IndexDist::Zipf`] constructor: the exponent must be
    /// a finite non-negative number (s = 0 degenerates to uniform,
    /// negative or NaN exponents would silently corrupt the sampler's
    /// harmonic-sum tables). The CLI's `--zipf <s>` parses through
    /// this, mirroring the open-loop `target_qps` validation.
    pub fn zipf(s: f64) -> Result<IndexDist> {
        if !s.is_finite() || s < 0.0 {
            return Err(EmberError::Workload(format!(
                "zipf exponent must be a finite non-negative number, got {s}"
            )));
        }
        Ok(IndexDist::Zipf(s))
    }
}

impl fmt::Display for IndexDist {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            IndexDist::Uniform => write!(f, "uniform"),
            IndexDist::Zipf(s) => write!(f, "zipf({s})"),
        }
    }
}

/// Deterministic synthetic DLRM request for load generation: `lookups`
/// random table rows per table, keyed by `(client, k)` so the CLI,
/// example and bench all produce the same stream for the same model
/// shape (keeping their generators from drifting apart). Uniform
/// indices; see [`synthetic_request_with`] for skewed draws.
pub fn synthetic_request(
    tables: usize,
    rows: usize,
    dense: usize,
    lookups: usize,
    client: usize,
    k: usize,
) -> Request {
    synthetic_request_with(tables, rows, dense, lookups, IndexDist::Uniform, client, k)
}

/// [`synthetic_request`] with an explicit index distribution. The
/// uniform path consumes the rng identically to the original
/// generator, so existing request streams are byte-identical.
pub fn synthetic_request_with(
    tables: usize,
    rows: usize,
    dense: usize,
    lookups: usize,
    dist: IndexDist,
    client: usize,
    k: usize,
) -> Request {
    let id = ((client as u64) << 32) | k as u64;
    let mut rng = Rng::new(id.wrapping_mul(0x9E37_79B9).wrapping_add(7));
    let zipf = match dist {
        IndexDist::Zipf(s) => Some(Zipf::new(rows.max(1) as u64, s)),
        IndexDist::Uniform => None,
    };
    Request {
        id,
        lookups: (0..tables)
            .map(|_| {
                (0..lookups)
                    .map(|_| match &zipf {
                        Some(z) => z.sample(&mut rng) as i32,
                        None => rng.below(rows as u64) as i32,
                    })
                    .collect()
            })
            .collect(),
        dense: (0..dense).map(|_| rng.f32()).collect(),
    }
}

/// Shape of one closed-loop load-generation run.
#[derive(Debug, Clone, Copy)]
pub struct LoadSpec {
    /// Concurrent closed-loop clients.
    pub clients: usize,
    /// Requests each client issues.
    pub requests_per_client: usize,
    /// Aggregate target arrival rate; `None` (or any non-positive
    /// value) = as fast as possible (each client limited only by its
    /// in-flight request).
    pub target_qps: Option<f64>,
    /// Index distribution the requests were generated with. Carried
    /// into [`LoadReport::dist`] so bench output records it; the
    /// request closure is still responsible for actually using it
    /// (via [`synthetic_request_with`]).
    pub dist: IndexDist,
    /// Per-request latency budget: each submit carries an absolute
    /// deadline of `now + deadline`, which the coordinator's QoS
    /// policy may enforce. `None` = no deadlines.
    pub deadline: Option<Duration>,
    /// Max retries per request after an `Overloaded` rejection. Each
    /// retry backs off with jittered exponential delay (see
    /// [`retry_backoff`]) instead of hammering the admission edge;
    /// `0` (the default) keeps the classic shed-and-move-on behavior.
    pub retry_budget: u32,
}

impl Default for LoadSpec {
    fn default() -> Self {
        LoadSpec {
            clients: 4,
            requests_per_client: 256,
            target_qps: None,
            dist: IndexDist::Uniform,
            deadline: None,
            retry_budget: 0,
        }
    }
}

/// Shape of one open-loop (Poisson-arrival) run.
#[derive(Debug, Clone, Copy)]
pub struct OpenLoopSpec {
    /// Mean aggregate arrival rate of the Poisson process.
    pub target_qps: f64,
    /// Total requests to issue.
    pub requests: usize,
    /// Seed for the arrival process (inter-arrival draws only; request
    /// contents stay keyed by request number).
    pub seed: u64,
    /// Threads draining response channels. Must exceed the server's
    /// concurrency only if response-wait itself is the bottleneck.
    pub collectors: usize,
    /// Index distribution, recorded into the report (see
    /// [`LoadSpec::dist`]).
    pub dist: IndexDist,
    /// Per-request latency budget (see [`LoadSpec::deadline`]).
    pub deadline: Option<Duration>,
    /// Max retries per request after a submit-time `Overloaded`
    /// rejection (see [`LoadSpec::retry_budget`]). Retries are
    /// rescheduled on the arrival thread after a jittered backoff, so
    /// they ride the same Poisson clock as fresh arrivals instead of
    /// stalling it.
    pub retry_budget: u32,
}

impl Default for OpenLoopSpec {
    fn default() -> Self {
        OpenLoopSpec {
            target_qps: 1000.0,
            requests: 256,
            seed: 1,
            collectors: 4,
            dist: IndexDist::Uniform,
            deadline: None,
            retry_budget: 0,
        }
    }
}

/// Client-side view of one run (server-side counters live in
/// [`super::ServeStats`]).
#[derive(Debug, Default, Clone, Copy)]
pub struct LoadReport {
    pub sent: u64,
    pub ok: u64,
    /// Requests the server refused or abandoned via admission control
    /// (`EmberError::Overloaded`) — deliberate QoS behavior under
    /// overload, counted apart from real failures.
    pub shed: u64,
    pub errors: u64,
    /// Retry attempts issued under the spec's `retry_budget` (a
    /// request that eventually succeeds after two backoffs counts two
    /// retries and one `ok`).
    pub retries: u64,
    pub wall: Duration,
    /// End-to-end latency measured at the client (submit → response).
    pub hist: LatencyHist,
    /// Index distribution the run was generated with.
    pub dist: IndexDist,
    /// The offered arrival rate (`None` for an unpaced closed loop,
    /// where the clients self-pace to the server's speed).
    pub offered_qps: Option<f64>,
}

impl LoadReport {
    /// Successful responses per second of wall-clock time.
    pub fn throughput_rps(&self) -> f64 {
        if self.wall.is_zero() {
            0.0
        } else {
            self.ok as f64 / self.wall.as_secs_f64()
        }
    }
    pub fn p50(&self) -> Duration {
        self.hist.quantile(0.50)
    }
    pub fn p95(&self) -> Duration {
        self.hist.quantile(0.95)
    }
    pub fn p99(&self) -> Duration {
        self.hist.quantile(0.99)
    }

    /// Header matching [`LoadReport::table_row`]'s columns (the caller
    /// prepends its own `target` column to both).
    pub fn table_header() -> String {
        format!(
            "{:>10}  {:>7}  {:>9}  {:>9}  {:>9}  {:>8}",
            "achieved", "shed", "p50", "p95", "p99", "retries"
        )
    }

    /// Shared row tail for latency/throughput tables
    /// (`achieved  shed  p50  p95  p99  retries`), so the CLI, example
    /// and bench render the sweep identically. `achieved` counts only
    /// served requests — goodput, not offered load.
    pub fn table_row(&self) -> String {
        format!(
            "{:>10.0}  {:>7}  {:>9.2?}  {:>9.2?}  {:>9.2?}  {:>8}",
            self.throughput_rps(),
            self.shed,
            self.p50(),
            self.p95(),
            self.p99(),
            self.retries
        )
    }
}

/// Jittered exponential backoff before retry `attempt` (1-based):
/// 1ms base doubling per attempt, capped at 16ms, plus a uniform
/// jitter of up to the same magnitude — synchronized clients shed by
/// one admission wave must not re-converge on the next.
fn retry_backoff(attempt: u32, rng: &mut Rng) -> Duration {
    let base_us = 1000u64 << attempt.saturating_sub(1).min(4);
    Duration::from_micros(base_us + rng.below(base_us))
}

/// Drive `coord` with `spec`, generating request `k` of client `c` via
/// `make_req(c, k)`. Blocks until every client finishes.
pub fn run_closed_loop<F>(coord: &Coordinator, spec: LoadSpec, make_req: F) -> Result<LoadReport>
where
    F: Fn(usize, usize) -> Request + Send + Sync,
{
    let clients = spec.clients.max(1);
    let pace = spec
        .target_qps
        .filter(|q| *q > 0.0)
        .map(|q| Duration::from_secs_f64(clients as f64 / q));
    let make_req = &make_req;
    let t0 = Instant::now();
    let mut results: Vec<(u64, u64, u64, u64, LatencyHist)> = Vec::with_capacity(clients);
    {
        let mut spawn_err = None;
        let mut panicked = 0usize;
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(clients);
            for c in 0..clients {
                let client = match coord.client() {
                    Ok(cl) => cl,
                    Err(e) => {
                        spawn_err = Some(e);
                        break;
                    }
                };
                handles.push(s.spawn(move || {
                    let mut hist = LatencyHist::default();
                    let (mut ok, mut shed, mut errors, mut retries) = (0u64, 0u64, 0u64, 0u64);
                    let mut backoff_rng = Rng::new(0xBAC0_FF ^ c as u64);
                    let mut next = Instant::now();
                    for k in 0..spec.requests_per_client {
                        if let Some(p) = pace {
                            let now = Instant::now();
                            if next > now {
                                std::thread::sleep(next - now);
                            }
                            next += p;
                        }
                        let t = Instant::now();
                        let mut attempts = 0u32;
                        loop {
                            // each attempt gets a fresh deadline — a
                            // retry is a new request, its budget restarts
                            let at = Instant::now();
                            let deadline = spec.deadline.map(|d| at + d);
                            match client.infer_with_deadline(make_req(c, k), deadline) {
                                Ok(_) => {
                                    // latency from first submit: backoff
                                    // waits are part of the retry cost
                                    hist.record(t.elapsed());
                                    ok += 1;
                                    break;
                                }
                                Err(EmberError::Overloaded(_))
                                    if attempts < spec.retry_budget =>
                                {
                                    attempts += 1;
                                    retries += 1;
                                    std::thread::sleep(retry_backoff(
                                        attempts,
                                        &mut backoff_rng,
                                    ));
                                }
                                // admission/deadline sheds are deliberate
                                // QoS behavior, not failures
                                Err(EmberError::Overloaded(_)) => {
                                    shed += 1;
                                    break;
                                }
                                Err(_) => {
                                    errors += 1;
                                    break;
                                }
                            }
                        }
                    }
                    (ok, shed, errors, retries, hist)
                }));
            }
            for h in handles {
                match h.join() {
                    Ok(r) => results.push(r),
                    Err(_) => panicked += 1,
                }
            }
        });
        if let Some(e) = spawn_err {
            return Err(e);
        }
        // a swallowed panic would silently zero this client's share of
        // the report — surface it instead
        if panicked > 0 {
            return Err(EmberError::Runtime(format!(
                "{panicked} load-generator client thread(s) panicked"
            )));
        }
    }
    let mut report = LoadReport {
        wall: t0.elapsed(),
        dist: spec.dist,
        offered_qps: spec.target_qps.filter(|q| *q > 0.0),
        ..Default::default()
    };
    for (ok, shed, errors, retries, hist) in results {
        report.ok += ok;
        report.shed += shed;
        report.errors += errors;
        report.retries += retries;
        report.sent += ok + shed + errors;
        report.hist.merge(&hist);
    }
    Ok(report)
}

/// Drive `coord` open-loop: submissions arrive as a Poisson process at
/// `spec.target_qps` whether or not earlier responses have come back,
/// so queueing delay at saturation shows up in the latency histogram
/// instead of being absorbed by generator back-pressure. One arrival
/// thread paces and submits; `spec.collectors` threads await the
/// response channels. `make_req(k)` builds request number `k`.
pub fn run_open_loop<F>(coord: &Coordinator, spec: OpenLoopSpec, make_req: F) -> Result<LoadReport>
where
    F: Fn(usize) -> Request + Send + Sync,
{
    if spec.target_qps.is_nan() || spec.target_qps <= 0.0 {
        return Err(EmberError::Workload(format!(
            "open-loop target_qps must be positive, got {}",
            spec.target_qps
        )));
    }
    let client = coord.client()?;
    let (tx, rx) = mpsc::channel::<(Instant, Receiver<Result<Response>>)>();
    let rx = Mutex::new(rx);
    let collectors = spec.collectors.max(1);
    let t0 = Instant::now();
    let mut submit_shed = 0u64;
    let mut submit_errors = 0u64;
    let mut submit_retries = 0u64;
    let mut results: Vec<(u64, u64, u64, LatencyHist)> = Vec::with_capacity(collectors);
    let mut panicked = 0usize;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..collectors)
            .map(|_| {
                s.spawn(|| {
                    let mut hist = LatencyHist::default();
                    let (mut ok, mut shed, mut errors) = (0u64, 0u64, 0u64);
                    loop {
                        // hold the lock only for the queue pop, not the
                        // response wait — collectors drain concurrently
                        let item = match rx.lock() {
                            Ok(g) => g.recv(),
                            Err(_) => break,
                        };
                        let Ok((t, resp_rx)) = item else { break };
                        match resp_rx.recv() {
                            Ok(Ok(_)) => {
                                hist.record(t.elapsed());
                                ok += 1;
                            }
                            // admitted, then shed at batch formation —
                            // deliberate QoS behavior, not a failure
                            Ok(Err(EmberError::Overloaded(_))) => shed += 1,
                            _ => errors += 1,
                        }
                    }
                    (ok, shed, errors, hist)
                })
            })
            .collect();

        // Poisson arrivals: exponential inter-arrival gaps with mean
        // 1/rate, submitted from this thread without awaiting replies.
        // Submit-time sheds reschedule onto `pending` (due-time, request
        // number, attempts-so-far) and fire from the same arrival clock
        // once their jittered backoff elapses — retries never stall the
        // Poisson process, and exhausted budgets fall through to `shed`.
        {
            let mut arrivals = Rng::new(spec.seed);
            let mut backoff_rng = Rng::new(spec.seed ^ 0xBAC0_FF);
            let mut pending: Vec<(Instant, usize, u32)> = Vec::new();
            let mut submit_one = |k: usize,
                                  attempts: u32,
                                  pending: &mut Vec<(Instant, usize, u32)>,
                                  backoff_rng: &mut Rng| {
                let submit_t = Instant::now();
                let deadline = spec.deadline.map(|d| submit_t + d);
                match client.submit_with_deadline(make_req(k), deadline) {
                    Ok(resp_rx) => {
                        let _ = tx.send((submit_t, resp_rx));
                    }
                    Err(EmberError::Overloaded(_)) if attempts < spec.retry_budget => {
                        submit_retries += 1;
                        let due = submit_t + retry_backoff(attempts + 1, backoff_rng);
                        pending.push((due, k, attempts + 1));
                    }
                    Err(EmberError::Overloaded(_)) => submit_shed += 1,
                    Err(_) => submit_errors += 1,
                }
            };
            let mut next = Instant::now();
            for k in 0..spec.requests {
                let u = arrivals.f64();
                next += Duration::from_secs_f64(-(1.0 - u).ln() / spec.target_qps);
                let now = Instant::now();
                if next > now {
                    std::thread::sleep(next - now);
                }
                // fire any retry whose backoff has elapsed (re-sheds
                // re-enter `pending` with a strictly future due time)
                let now = Instant::now();
                let mut i = 0;
                while i < pending.len() {
                    if pending[i].0 <= now {
                        let (_, rk, att) = pending.swap_remove(i);
                        submit_one(rk, att, &mut pending, &mut backoff_rng);
                    } else {
                        i += 1;
                    }
                }
                submit_one(k, 0, &mut pending, &mut backoff_rng);
            }
            // drain the retries still backing off after the last arrival
            while !pending.is_empty() {
                let earliest = pending.iter().map(|p| p.0).min().unwrap();
                let now = Instant::now();
                if earliest > now {
                    std::thread::sleep(earliest - now);
                }
                let now = Instant::now();
                let mut i = 0;
                while i < pending.len() {
                    if pending[i].0 <= now {
                        let (_, rk, att) = pending.swap_remove(i);
                        submit_one(rk, att, &mut pending, &mut backoff_rng);
                    } else {
                        i += 1;
                    }
                }
            }
        }
        drop(tx); // collectors drain the queue then fall out of recv

        for h in handles {
            match h.join() {
                Ok(r) => results.push(r),
                Err(_) => panicked += 1,
            }
        }
    });
    if panicked > 0 {
        return Err(EmberError::Runtime(format!("{panicked} open-loop collector(s) panicked")));
    }
    let mut report = LoadReport {
        wall: t0.elapsed(),
        dist: spec.dist,
        offered_qps: Some(spec.target_qps),
        shed: submit_shed,
        errors: submit_errors,
        retries: submit_retries,
        sent: submit_shed + submit_errors,
        ..Default::default()
    };
    for (ok, shed, errors, hist) in results {
        report.ok += ok;
        report.shed += shed;
        report.errors += errors;
        report.sent += ok + shed + errors;
        report.hist.merge(&hist);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatchOptions, DlrmModel, ServeOptions};
    use crate::util::rng::Rng;

    fn make_req(m: &DlrmModel, c: usize, k: usize) -> Request {
        let mut rng = Rng::new((c as u64) << 32 | k as u64);
        Request {
            id: ((c as u64) << 32) | k as u64,
            lookups: (0..m.num_tables)
                .map(|_| (0..4).map(|_| rng.below(m.table_rows as u64) as i32).collect())
                .collect(),
            dense: (0..m.dense).map(|_| rng.f32()).collect(),
        }
    }

    #[test]
    fn zipf_constructor_rejects_nan_negative_and_infinite_exponents() {
        assert!(IndexDist::zipf(f64::NAN).is_err());
        assert!(IndexDist::zipf(-0.5).is_err());
        assert!(IndexDist::zipf(f64::INFINITY).is_err());
        assert_eq!(IndexDist::zipf(0.0).unwrap(), IndexDist::Zipf(0.0));
        assert_eq!(IndexDist::zipf(1.05).unwrap(), IndexDist::Zipf(1.05));
    }

    #[test]
    fn closed_loop_completes_every_request() {
        let model = DlrmModel::new(4, 64, 8, 2, 6, 3, 16, 42).unwrap();
        let shape = DlrmModel::new(4, 64, 8, 2, 6, 3, 16, 42).unwrap();
        let coord = Coordinator::start_sharded(
            model,
            None,
            ServeOptions {
                batch: BatchOptions {
                    max_batch: 4,
                    max_wait: Duration::from_millis(1),
                    ..Default::default()
                },
                shards: 2,
                ..Default::default()
            },
        );
        let spec = LoadSpec { clients: 3, requests_per_client: 10, ..Default::default() };
        let report = run_closed_loop(&coord, spec, |c, k| make_req(&shape, c, k)).unwrap();
        assert_eq!(report.sent, 30);
        assert_eq!(report.ok, 30);
        assert_eq!(report.errors, 0);
        assert_eq!(report.hist.count(), 30);
        assert!(report.throughput_rps() > 0.0);
        assert!(report.p99() >= report.p50());
        let stats = coord.shutdown();
        assert_eq!(stats.requests, 30);
    }

    #[test]
    fn paced_load_respects_target_qps_upper_bound() {
        let model = DlrmModel::new(4, 64, 8, 1, 6, 3, 16, 1).unwrap();
        let shape = DlrmModel::new(4, 64, 8, 1, 6, 3, 16, 1).unwrap();
        let coord = Coordinator::start(
            model,
            None,
            BatchOptions { max_batch: 4, max_wait: Duration::from_micros(200), ..Default::default() },
        );
        // 20 requests at 200 qps => at least ~95ms of pacing
        let spec = LoadSpec {
            clients: 2,
            requests_per_client: 10,
            target_qps: Some(200.0),
            ..Default::default()
        };
        let t0 = Instant::now();
        let report = run_closed_loop(&coord, spec, |c, k| make_req(&shape, c, k)).unwrap();
        assert_eq!(report.ok, 20);
        assert!(t0.elapsed() >= Duration::from_millis(80), "pacing was ignored");
        assert!(report.throughput_rps() <= 300.0, "{}", report.throughput_rps());
        assert_eq!(report.offered_qps, Some(200.0));
        coord.shutdown();
    }

    #[test]
    fn uniform_dist_is_byte_identical_to_the_legacy_generator() {
        for (c, k) in [(0usize, 0usize), (3, 17), (7, 1000)] {
            let old = synthetic_request(4, 512, 13, 6, c, k);
            let new = synthetic_request_with(4, 512, 13, 6, IndexDist::Uniform, c, k);
            assert_eq!(old, new, "client {c} request {k}");
        }
    }

    #[test]
    fn zipf_dist_skews_toward_hot_rows_and_stays_in_range() {
        let rows = 1024usize;
        let mut head = 0u64; // draws landing in the hottest 1% of rows
        let mut total = 0u64;
        for k in 0..200 {
            let r = synthetic_request_with(2, rows, 0, 8, IndexDist::Zipf(1.1), 0, k);
            for l in &r.lookups {
                for &i in l {
                    assert!(i >= 0 && (i as usize) < rows, "index {i} out of range");
                    if (i as usize) < rows / 100 {
                        head += 1;
                    }
                    total += 1;
                }
            }
        }
        assert_eq!(total, 200 * 2 * 8);
        // Under uniform the hottest 1% would get ~1% of draws; zipf(1.1)
        // concentrates far more. 20% is a very safe lower bound.
        assert!(
            head as f64 / total as f64 > 0.20,
            "zipf skew missing: {head}/{total} in the top 1%"
        );
        // Determinism: same (client, k) ⇒ same request.
        assert_eq!(
            synthetic_request_with(2, rows, 0, 8, IndexDist::Zipf(1.1), 0, 5),
            synthetic_request_with(2, rows, 0, 8, IndexDist::Zipf(1.1), 0, 5),
        );
    }

    #[test]
    fn index_dist_displays_for_bench_output() {
        assert_eq!(IndexDist::Uniform.to_string(), "uniform");
        assert_eq!(IndexDist::Zipf(1.05).to_string(), "zipf(1.05)");
        assert_eq!(IndexDist::default(), IndexDist::Uniform);
    }

    #[test]
    fn open_loop_completes_every_request_and_records_offered_rate() {
        let model = DlrmModel::new(4, 64, 8, 2, 6, 3, 16, 42).unwrap();
        let coord = Coordinator::start(
            model,
            None,
            BatchOptions { max_batch: 4, max_wait: Duration::from_millis(1), ..Default::default() },
        );
        let spec = OpenLoopSpec {
            target_qps: 5000.0,
            requests: 24,
            collectors: 3,
            ..Default::default()
        };
        let report =
            run_open_loop(&coord, spec, |k| synthetic_request(2, 64, 3, 6, 0, k)).unwrap();
        assert_eq!(report.sent, 24);
        assert_eq!(report.ok, 24);
        assert_eq!(report.errors, 0);
        assert_eq!(report.hist.count(), 24);
        assert_eq!(report.offered_qps, Some(5000.0));
        let stats = coord.shutdown();
        assert_eq!(stats.requests, 24);
    }

    /// `Overloaded` responses land in `shed`, never `errors`: every
    /// request carries a 1ms deadline but the batch timer is 20ms, so
    /// under the `deadline` policy all of them are shed at batch
    /// formation and the report must say exactly that.
    #[test]
    fn closed_loop_counts_sheds_separately_from_errors() {
        use crate::qos::{QosOptions, ShedPolicy};
        let model = DlrmModel::new(4, 64, 8, 1, 6, 3, 16, 1).unwrap();
        let shape = DlrmModel::new(4, 64, 8, 1, 6, 3, 16, 1).unwrap();
        let coord = Coordinator::start_sharded(
            model,
            None,
            ServeOptions {
                batch: BatchOptions {
                    max_batch: 64,
                    max_wait: Duration::from_millis(20),
                    ..Default::default()
                },
                shards: 1,
                qos: QosOptions { queue_depth: 0, policy: ShedPolicy::Deadline },
                threads: 1,
            },
        );
        let spec = LoadSpec {
            clients: 2,
            requests_per_client: 3,
            deadline: Some(Duration::from_millis(1)),
            ..Default::default()
        };
        let report = run_closed_loop(&coord, spec, |c, k| make_req(&shape, c, k)).unwrap();
        assert_eq!(report.sent, 6);
        assert_eq!(report.shed, 6, "every deadline expires before the 20ms flush");
        assert_eq!(report.ok, 0);
        assert_eq!(report.errors, 0, "sheds are not failures");
        assert_eq!(report.hist.count(), 0);
        let stats = coord.shutdown();
        assert_eq!(stats.shed_batch, 6);
        assert_eq!(stats.errors, 0);
    }

    /// Retry budget turns transient admission sheds into eventual
    /// successes: a depth-1 queue in front of a batch-of-1 worker sheds
    /// most of a 4-client burst on first contact, but with 8 retries
    /// and millisecond backoffs every request lands. The report must
    /// show the backoff work (`retries > 0`) and zero residual sheds.
    #[test]
    fn closed_loop_retry_budget_converts_sheds_into_successes() {
        use crate::qos::{QosOptions, ShedPolicy};
        let model = DlrmModel::new(1, 64, 8, 1, 6, 3, 16, 1).unwrap();
        let shape = DlrmModel::new(1, 64, 8, 1, 6, 3, 16, 1).unwrap();
        let coord = Coordinator::start_sharded(
            model,
            None,
            ServeOptions {
                batch: BatchOptions {
                    max_batch: 1,
                    max_wait: Duration::from_millis(1),
                    ..Default::default()
                },
                shards: 1,
                qos: QosOptions { queue_depth: 1, policy: ShedPolicy::Ewma },
                threads: 1,
            },
        );
        let spec = LoadSpec {
            clients: 4,
            requests_per_client: 8,
            retry_budget: 32,
            ..Default::default()
        };
        let report = run_closed_loop(&coord, spec, |c, k| make_req(&shape, c, k)).unwrap();
        assert_eq!(report.sent, 32);
        assert_eq!(report.ok, 32, "a 32-retry budget must absorb a depth-1 queue");
        assert_eq!(report.shed, 0);
        assert_eq!(report.errors, 0);
        assert!(report.retries > 0, "contention on a depth-1 queue must trigger retries");
        coord.shutdown();
    }

    /// Open-loop retries reschedule on the arrival thread: with the
    /// same depth-1 bottleneck, a fast Poisson burst sheds at submit
    /// time, and the retry budget must resubmit (and drain the pending
    /// queue after the last arrival) instead of losing those requests.
    #[test]
    fn open_loop_retry_budget_resubmits_after_backoff() {
        use crate::qos::{QosOptions, ShedPolicy};
        let model = DlrmModel::new(1, 64, 8, 1, 6, 3, 16, 1).unwrap();
        let coord = Coordinator::start_sharded(
            model,
            None,
            ServeOptions {
                batch: BatchOptions {
                    max_batch: 1,
                    max_wait: Duration::from_millis(1),
                    ..Default::default()
                },
                shards: 1,
                qos: QosOptions { queue_depth: 1, policy: ShedPolicy::Ewma },
                threads: 1,
            },
        );
        let spec = OpenLoopSpec {
            target_qps: 200_000.0,
            requests: 32,
            collectors: 2,
            retry_budget: 64,
            ..Default::default()
        };
        let report =
            run_open_loop(&coord, spec, |k| synthetic_request(1, 64, 3, 6, 0, k)).unwrap();
        assert_eq!(report.sent, 32, "retries must not double-count sent requests");
        assert_eq!(report.ok, 32, "the retry budget must absorb submit-time sheds");
        assert_eq!(report.errors, 0);
        assert!(report.retries > 0, "a 50k-qps burst into a depth-1 queue must retry");
        coord.shutdown();
    }

    #[test]
    fn open_loop_rejects_nonpositive_rates() {
        let model = DlrmModel::new(4, 64, 8, 1, 6, 3, 16, 1).unwrap();
        let coord = Coordinator::start(model, None, BatchOptions::default());
        for qps in [0.0, -5.0, f64::NAN] {
            let spec = OpenLoopSpec { target_qps: qps, requests: 1, ..Default::default() };
            assert!(
                run_open_loop(&coord, spec, |k| synthetic_request(1, 64, 3, 6, 0, k)).is_err(),
                "qps {qps} accepted"
            );
        }
        coord.shutdown();
    }
}
