//! Closed-loop load generator for the serving engine.
//!
//! `clients` threads each own a cloned [`CoordinatorClient`] and issue
//! requests back-to-back (classic closed loop). With a `target_qps`
//! each client paces its submissions so the coordinator sees an
//! aggregate arrival rate of ~`target_qps`; sweeping the target and
//! plotting [`LoadReport::throughput_rps`] against the report's
//! latency quantiles gives the latency/throughput curve.

use super::server::Coordinator;
use super::stats::LatencyHist;
use super::Request;
use crate::error::{EmberError, Result};
use crate::util::rng::Rng;
use std::time::{Duration, Instant};

/// Deterministic synthetic DLRM request for load generation: `lookups`
/// random table rows per table, keyed by `(client, k)` so the CLI,
/// example and bench all produce the same stream for the same model
/// shape (keeping their generators from drifting apart).
pub fn synthetic_request(
    tables: usize,
    rows: usize,
    dense: usize,
    lookups: usize,
    client: usize,
    k: usize,
) -> Request {
    let id = ((client as u64) << 32) | k as u64;
    let mut rng = Rng::new(id.wrapping_mul(0x9E37_79B9).wrapping_add(7));
    Request {
        id,
        lookups: (0..tables)
            .map(|_| (0..lookups).map(|_| rng.below(rows as u64) as i32).collect())
            .collect(),
        dense: (0..dense).map(|_| rng.f32()).collect(),
    }
}

/// Shape of one load-generation run.
#[derive(Debug, Clone, Copy)]
pub struct LoadSpec {
    /// Concurrent closed-loop clients.
    pub clients: usize,
    /// Requests each client issues.
    pub requests_per_client: usize,
    /// Aggregate target arrival rate; `None` (or any non-positive
    /// value) = as fast as possible (each client limited only by its
    /// in-flight request).
    pub target_qps: Option<f64>,
}

impl Default for LoadSpec {
    fn default() -> Self {
        LoadSpec { clients: 4, requests_per_client: 256, target_qps: None }
    }
}

/// Client-side view of one run (server-side counters live in
/// [`super::ServeStats`]).
#[derive(Debug, Default, Clone, Copy)]
pub struct LoadReport {
    pub sent: u64,
    pub ok: u64,
    pub errors: u64,
    pub wall: Duration,
    /// End-to-end latency measured at the client (submit → response).
    pub hist: LatencyHist,
}

impl LoadReport {
    /// Successful responses per second of wall-clock time.
    pub fn throughput_rps(&self) -> f64 {
        if self.wall.is_zero() {
            0.0
        } else {
            self.ok as f64 / self.wall.as_secs_f64()
        }
    }
    pub fn p50(&self) -> Duration {
        self.hist.quantile(0.50)
    }
    pub fn p95(&self) -> Duration {
        self.hist.quantile(0.95)
    }
    pub fn p99(&self) -> Duration {
        self.hist.quantile(0.99)
    }

    /// Header matching [`LoadReport::table_row`]'s columns (the caller
    /// prepends its own `target` column to both).
    pub fn table_header() -> String {
        format!("{:>10}  {:>9}  {:>9}  {:>9}", "achieved", "p50", "p95", "p99")
    }

    /// Shared row tail for latency/throughput tables
    /// (`achieved  p50  p95  p99`), so the CLI, example and bench
    /// render the sweep identically.
    pub fn table_row(&self) -> String {
        format!(
            "{:>10.0}  {:>9.2?}  {:>9.2?}  {:>9.2?}",
            self.throughput_rps(),
            self.p50(),
            self.p95(),
            self.p99()
        )
    }
}

/// Drive `coord` with `spec`, generating request `k` of client `c` via
/// `make_req(c, k)`. Blocks until every client finishes.
pub fn run_closed_loop<F>(coord: &Coordinator, spec: LoadSpec, make_req: F) -> Result<LoadReport>
where
    F: Fn(usize, usize) -> Request + Send + Sync,
{
    let clients = spec.clients.max(1);
    let pace = spec
        .target_qps
        .filter(|q| *q > 0.0)
        .map(|q| Duration::from_secs_f64(clients as f64 / q));
    let make_req = &make_req;
    let t0 = Instant::now();
    let mut results: Vec<(u64, u64, LatencyHist)> = Vec::with_capacity(clients);
    {
        let mut spawn_err = None;
        let mut panicked = 0usize;
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(clients);
            for c in 0..clients {
                let client = match coord.client() {
                    Ok(cl) => cl,
                    Err(e) => {
                        spawn_err = Some(e);
                        break;
                    }
                };
                handles.push(s.spawn(move || {
                    let mut hist = LatencyHist::default();
                    let (mut ok, mut errors) = (0u64, 0u64);
                    let mut next = Instant::now();
                    for k in 0..spec.requests_per_client {
                        if let Some(p) = pace {
                            let now = Instant::now();
                            if next > now {
                                std::thread::sleep(next - now);
                            }
                            next += p;
                        }
                        let t = Instant::now();
                        match client.infer(make_req(c, k)) {
                            Ok(_) => {
                                hist.record(t.elapsed());
                                ok += 1;
                            }
                            Err(_) => errors += 1,
                        }
                    }
                    (ok, errors, hist)
                }));
            }
            for h in handles {
                match h.join() {
                    Ok(r) => results.push(r),
                    Err(_) => panicked += 1,
                }
            }
        });
        if let Some(e) = spawn_err {
            return Err(e);
        }
        // a swallowed panic would silently zero this client's share of
        // the report — surface it instead
        if panicked > 0 {
            return Err(EmberError::Runtime(format!(
                "{panicked} load-generator client thread(s) panicked"
            )));
        }
    }
    let mut report = LoadReport { wall: t0.elapsed(), ..Default::default() };
    for (ok, errors, hist) in results {
        report.ok += ok;
        report.errors += errors;
        report.sent += ok + errors;
        report.hist.merge(&hist);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatchOptions, DlrmModel, ServeOptions};
    use crate::util::rng::Rng;

    fn make_req(m: &DlrmModel, c: usize, k: usize) -> Request {
        let mut rng = Rng::new((c as u64) << 32 | k as u64);
        Request {
            id: ((c as u64) << 32) | k as u64,
            lookups: (0..m.num_tables)
                .map(|_| (0..4).map(|_| rng.below(m.table_rows as u64) as i32).collect())
                .collect(),
            dense: (0..m.dense).map(|_| rng.f32()).collect(),
        }
    }

    #[test]
    fn closed_loop_completes_every_request() {
        let model = DlrmModel::new(4, 64, 8, 2, 6, 3, 16, 42).unwrap();
        let shape = DlrmModel::new(4, 64, 8, 2, 6, 3, 16, 42).unwrap();
        let coord = Coordinator::start_sharded(
            model,
            None,
            ServeOptions {
                batch: BatchOptions {
                    max_batch: 4,
                    max_wait: Duration::from_millis(1),
                },
                shards: 2,
            },
        );
        let spec = LoadSpec { clients: 3, requests_per_client: 10, target_qps: None };
        let report = run_closed_loop(&coord, spec, |c, k| make_req(&shape, c, k)).unwrap();
        assert_eq!(report.sent, 30);
        assert_eq!(report.ok, 30);
        assert_eq!(report.errors, 0);
        assert_eq!(report.hist.count(), 30);
        assert!(report.throughput_rps() > 0.0);
        assert!(report.p99() >= report.p50());
        let stats = coord.shutdown();
        assert_eq!(stats.requests, 30);
    }

    #[test]
    fn paced_load_respects_target_qps_upper_bound() {
        let model = DlrmModel::new(4, 64, 8, 1, 6, 3, 16, 1).unwrap();
        let shape = DlrmModel::new(4, 64, 8, 1, 6, 3, 16, 1).unwrap();
        let coord = Coordinator::start(
            model,
            None,
            BatchOptions { max_batch: 4, max_wait: Duration::from_micros(200) },
        );
        // 20 requests at 200 qps => at least ~95ms of pacing
        let spec = LoadSpec { clients: 2, requests_per_client: 10, target_qps: Some(200.0) };
        let t0 = Instant::now();
        let report = run_closed_loop(&coord, spec, |c, k| make_req(&shape, c, k)).unwrap();
        assert_eq!(report.ok, 20);
        assert!(t0.elapsed() >= Duration::from_millis(80), "pacing was ignored");
        assert!(report.throughput_rps() <= 300.0, "{}", report.throughput_rps());
        coord.shutdown();
    }
}
